"""Pod-scale distributed search demo on fake devices.

MUST run as its own process (device count is locked at first jax import):
    PYTHONPATH=src python examples/distributed_search.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax                  # noqa: E402
import jax.numpy as jnp     # noqa: E402
import numpy as np          # noqa: E402

from repro import compat                                      # noqa: E402
from repro.core.build import build_graph                      # noqa: E402
from repro.core.distributed import make_distributed_search    # noqa: E402
from repro.core.search import brute_force_topk, recall_at_k   # noqa: E402
from repro.core.types import SearchParams                     # noqa: E402
from repro.launch.mesh import make_test_mesh                  # noqa: E402


def main():
    mesh = make_test_mesh((4, 2), ("data", "model"))
    print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))}")
    sp = SearchParams(k=10, pool=64, max_iters=96)
    step = make_distributed_search(mesh, sp, data_axes=("data",),
                                   query_axis="model")

    N, D, R, S = 8000, 32, 16, 4
    rng = np.random.default_rng(0)
    vecs = rng.normal(size=(N, D)).astype(np.float32)
    print(f"building {S} per-shard subgraphs ({N // S} vectors each)...")
    parts = [build_graph(vecs[i * N // S:(i + 1) * N // S], R)
             for i in range(S)]
    idx = {
        "vectors": np.concatenate([np.asarray(g.vectors) for g in parts]),
        "nbrs": np.concatenate([np.asarray(g.nbrs) for g in parts]),
        "alive": np.concatenate([np.asarray(g.alive) for g in parts]),
        "e_in": np.concatenate([np.asarray(g.e_in) for g in parts]),
        "cache_vectors": np.zeros((S * 256, D), np.float32),
        "slot_hid": np.full((S * 256,), -1, np.int32),
        "h2d": np.full((N,), -1, np.int32),
        "f_recent": np.zeros((N,), np.float32),
    }
    Q = rng.normal(size=(64, D)).astype(np.float32)
    with compat.use_mesh(mesh):
        jidx = {k: jnp.asarray(v) for k, v in idx.items()}
        ids, dists = jax.jit(step)(jidx, jnp.asarray(Q), jax.random.PRNGKey(0))
        ids.block_until_ready()
    truth, _ = brute_force_topk(build_graph(vecs, R), jnp.asarray(Q), 10)
    print("distributed recall@10:",
          float(recall_at_k(jnp.asarray(np.asarray(ids)), truth)))


if __name__ == "__main__":
    main()
