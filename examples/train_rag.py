"""Train a small LM for a few hundred steps with checkpoint/restart, then
serve it with retrieval-augmented generation over a live SVFusion index.

Run: PYTHONPATH=src python examples/train_rag.py [--steps 200]
"""
import argparse
import tempfile

import jax
import numpy as np

from repro.configs.base import load_smoke_config
from repro.core.engine import EngineConfig
from repro.core.types import SearchParams
from repro.models import model as Mdl
from repro.serve.engine import Request, ServeEngine
from repro.serve.rag import Doc, RAGPipeline
from repro.train import train_loop


def main(steps=200):
    cfg = load_smoke_config("smollm_135m").replace(vocab=512)
    with tempfile.TemporaryDirectory() as ckpt:
        print(f"training {steps} steps (atomic async checkpoints -> {ckpt})")
        res = train_loop.run(cfg, steps=steps, batch=8, seq=64,
                             ckpt_dir=ckpt, ckpt_every=50)
        print(f"loss: {res.losses[0]:.3f} -> {res.losses[-1]:.3f}")
        assert res.losses[-1] < res.losses[0]

        # simulate a crash + restart: run() resumes from the checkpoint
        res2 = train_loop.run(cfg, steps=steps + 20, batch=8, seq=64,
                              ckpt_dir=ckpt, ckpt_every=50)
        print(f"resumed from step {res2.restored_from}, "
              f"ran {len(res2.losses)} more steps")

    params = Mdl.init_params(cfg, jax.random.PRNGKey(0))
    print("spinning up RAG pipeline with live index...")
    rag = RAGPipeline(cfg, params, EngineConfig(
        degree=16, cache_slots=512, capacity=1 << 14,
        search=SearchParams(k=4, pool=48, max_iters=64)))
    rng = np.random.default_rng(0)
    docs = [Doc(i, rng.integers(0, cfg.vocab, size=24).astype(np.int32))
            for i in range(200)]
    rag.ingest(docs)
    prompt = docs[11].tokens[:12]
    aug = rag.augment(prompt, k=2, budget=48)
    print(f"prompt {len(prompt)} tokens -> augmented {len(aug)} tokens")

    print("serving with continuous batching...")
    serve = ServeEngine(cfg, params, slots=4, max_len=128)
    for i in range(6):
        serve.submit(Request(rid=i, prompt=rag.augment(
            docs[i].tokens[:8], k=1, budget=16), max_new=8))
    serve.run_until_drained()
    print(f"completed {len(serve.completed)} generations; "
          f"stragglers re-dispatched: {serve.stragglers}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    main(ap.parse_args().steps)
