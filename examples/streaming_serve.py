"""End-to-end streaming SANNS driver (the paper's serving scenario).

Replays a SlidingWindow workload through the multi-stream engine —
concurrent search streams + a dedicated update stream with adaptive
batching — and reports throughput/recall/latency, mirroring Fig. 7/8.

Run: PYTHONPATH=src:. python examples/streaming_serve.py
"""
import time

import numpy as np

from repro.core.engine import EngineConfig, MultiStreamRunner, SVFusionEngine
from repro.core.types import SearchParams
from repro.train.data import sliding_window
from repro.utils import percentile


def main():
    dim = 32
    eng = SVFusionEngine(
        np.random.default_rng(9).normal(size=(64, dim)).astype(np.float32),
        EngineConfig(degree=16, cache_slots=1024, capacity=1 << 15,
                     search=SearchParams(k=10, pool=64, max_iters=96)))
    runner = MultiStreamRunner(eng, n_search_streams=2, max_batch=32)
    runner.start()

    # warm the jit caches before measuring
    eng.search(np.zeros((8, dim), np.float32))
    eng.insert(np.zeros((100, dim), np.float32))

    n_search = n_insert = 0
    t0 = time.perf_counter()
    for op in sliding_window(n=8000, dim=dim, t_max=80):
        if op.kind == "insert":
            runner.submit_insert(op.vectors)
            n_insert += len(op.vectors)
        elif op.kind == "delete":
            runner.submit_delete(op.ids)
        else:
            runner.submit_search(op.queries)
            n_search += len(op.queries)
    runner.drain_and_stop(timeout=300)
    dt = time.perf_counter() - t0

    lats = sorted(r[2] for r in runner.results)
    print(f"stream drained in {dt:.1f}s | searches={n_search} "
          f"inserts={n_insert}")
    print(f"search p50={percentile(lats, 50)*1e3:.1f}ms "
          f"p99={percentile(lats, 99)*1e3:.1f}ms")
    print("engine stats:", eng.stats())
    # orderly shutdown: a background consolidation may still be mid-jit
    # (the coalesced+speculative stream drains faster than consolidation)
    # and exiting across a live XLA dispatch aborts the interpreter
    eng.close()


if __name__ == "__main__":
    main()
