"""Quickstart: build an SVFusion index, search it, stream updates.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.engine import EngineConfig, SVFusionEngine
from repro.core.types import SearchParams


def main():
    rng = np.random.default_rng(0)
    dim = 64
    base = rng.normal(size=(20_000, dim)).astype(np.float32)

    print("building index (20k x 64)...")
    engine = SVFusionEngine(base, EngineConfig(
        degree=32, cache_slots=2048, capacity=1 << 16,
        search=SearchParams(k=10, pool=64, max_iters=96)))

    queries = base[:8] + rng.normal(scale=0.05, size=(8, dim)).astype(np.float32)
    ids, dists = engine.search(queries)
    print("top-1 self-hit:", (ids[:, 0] == np.arange(8)).mean())

    print("inserting 1k fresh vectors...")
    fresh = rng.normal(size=(1024, dim)).astype(np.float32)
    new_ids = engine.insert(fresh)
    got, _ = engine.search(fresh[:16])
    print("read-after-write@1:", (got[:, 0] == new_ids[:16]).mean())

    print("deleting 3k vectors (lazy + async repair)...")
    engine.delete(np.arange(3000))
    engine.wait_background()
    print("stats:", engine.stats())


if __name__ == "__main__":
    main()
