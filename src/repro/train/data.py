"""Data pipelines.

* ``TokenPipeline`` — deterministic synthetic LM token stream with a
  background prefetch thread (double-buffered host->device).
* Streaming-vector workload generators (paper §6.1): SlidingWindow,
  ExpirationTime, Clustered, MSTuring-IH — each yields a sequence of
  (op, payload) steps over a base vector dataset, mirroring the 2023
  Big ANN Challenge streaming track semantics at reduced scale.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np


# ---------------------------------------------------------------------------
# LM token pipeline
# ---------------------------------------------------------------------------

class TokenPipeline:
    """Synthetic-but-structured token batches (Zipfian unigram + repeated
    n-grams so the loss actually falls) with background prefetch."""

    def __init__(self, vocab: int, batch: int, seq: int, seed=0,
                 prefetch=2):
        self.vocab, self.batch, self.seq = vocab, batch, seq
        self.rng = np.random.default_rng(seed)
        ranks = np.arange(1, vocab + 1, dtype=np.float64)
        self.probs = (1.0 / ranks) / np.sum(1.0 / ranks)
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._th = threading.Thread(target=self._worker, daemon=True)
        self._th.start()

    def _make(self):
        toks = self.rng.choice(self.vocab, size=(self.batch, self.seq),
                               p=self.probs).astype(np.int32)
        # inject learnable bigram structure: even positions predict odd
        toks[:, 1::2] = (toks[:, 0::2] * 7 + 13) % self.vocab
        return {"tokens": toks, "labels": np.roll(toks, -1, axis=1),
                "mask": np.ones((self.batch, self.seq), np.float32)}

    def _worker(self):
        while not self._stop.is_set():
            try:
                self._q.put(self._make(), timeout=0.1)
            except queue.Full:
                continue

    def __iter__(self):
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            self._q.get_nowait()
        except queue.Empty:
            pass
        self._th.join(timeout=2.0)


# ---------------------------------------------------------------------------
# Streaming SANNS workloads (paper §6.1)
# ---------------------------------------------------------------------------

@dataclass
class StreamOp:
    kind: str                   # insert | delete | search
    vectors: Optional[np.ndarray] = None
    ids: Optional[np.ndarray] = None
    queries: Optional[np.ndarray] = None


def _base_data(n, dim, seed, clustered=False, n_clusters=64):
    rng = np.random.default_rng(seed)
    if not clustered:
        return rng.normal(size=(n, dim)).astype(np.float32), None
    centers = rng.normal(scale=4.0, size=(n_clusters, dim))
    assign = rng.integers(n_clusters, size=n)
    data = (centers[assign] + rng.normal(size=(n, dim))).astype(np.float32)
    return data, assign


def sliding_window(n=20000, dim=32, t_max=200, queries_per_step=8, seed=0
                   ) -> Iterator[StreamOp]:
    """Insert one of T_max segments per step; from T_max/2+1 delete the
    segment inserted T_max/2 steps earlier (paper SlidingWindow)."""
    data, _ = _base_data(n, dim, seed)
    rng = np.random.default_rng(seed + 1)
    seg = n // t_max
    bounds = [(i * seg, (i + 1) * seg) for i in range(t_max)]
    for t in range(t_max):
        s, e = bounds[t]
        yield StreamOp("insert", vectors=data[s:e])
        if t >= t_max // 2:
            ds, de = bounds[t - t_max // 2]
            yield StreamOp("delete", ids=np.arange(ds, de))
        if t > t_max // 2:
            q = data[rng.integers(bounds[max(0, t - 50)][0], e,
                                  queries_per_step)]
            yield StreamOp("search", queries=q
                           + rng.normal(scale=0.05, size=(queries_per_step,
                                                          dim)).astype(np.float32))


def expiration_time(n=20000, dim=32, t_max=100, queries_per_step=8, seed=0
                    ) -> Iterator[StreamOp]:
    """Lifetimes short(10):long(50):permanent(100) in 10:2:1 ratio."""
    data, _ = _base_data(n, dim, seed)
    rng = np.random.default_rng(seed + 1)
    per_step = n // t_max
    life_choices = np.array([10, 50, 100])
    life_probs = np.array([10, 2, 1], np.float64)
    life_probs /= life_probs.sum()
    expiry: dict[int, list] = {}
    nxt = 0
    for t in range(t_max):
        ids = np.arange(nxt, min(nxt + per_step, n))
        nxt += per_step
        if len(ids) == 0:
            break
        yield StreamOp("insert", vectors=data[ids])
        lives = rng.choice(life_choices, size=len(ids), p=life_probs)
        for lid, lf in zip(ids, lives):
            expiry.setdefault(t + int(lf), []).append(lid)
        if t in expiry:
            yield StreamOp("delete", ids=np.asarray(expiry.pop(t)))
        if t > 3:
            q = data[rng.integers(0, nxt, queries_per_step)]
            yield StreamOp("search", queries=q + rng.normal(
                scale=0.05, size=q.shape).astype(np.float32))


def clustered(n=20000, dim=32, rounds=5, n_clusters=64, queries_per_step=8,
              seed=0) -> Iterator[StreamOp]:
    """k-means clusters; each round inserts then deletes random cluster
    subsets -> strong distribution shift (paper Clustered)."""
    data, assign = _base_data(n, dim, seed, clustered=True,
                              n_clusters=n_clusters)
    rng = np.random.default_rng(seed + 1)
    inserted = np.zeros(n, bool)
    next_free = 0
    id_of = np.full(n, -1, np.int64)
    for r in range(rounds):
        for c in range(n_clusters):
            members = np.where((assign == c) & ~inserted)[0]
            take = members[:max(1, len(members) // (rounds - r))]
            if len(take):
                id_of[take] = np.arange(next_free, next_free + len(take))
                next_free += len(take)
                inserted[take] = True
                yield StreamOp("insert", vectors=data[take])
            if c % 8 == 7 and inserted.any():   # interleave searches so
                # truncated replays still measure recall (paper runs full)
                q_src = np.where(inserted)[0]
                if len(q_src) >= queries_per_step:
                    q = data[rng.choice(q_src, queries_per_step,
                                        replace=False)]
                    yield StreamOp("search", queries=q + rng.normal(
                        scale=0.05, size=q.shape).astype(np.float32))
        # delete a random fraction of some clusters
        for c in rng.choice(n_clusters, size=n_clusters // 4, replace=False):
            members = np.where((assign == c) & inserted)[0]
            drop = members[rng.random(len(members)) < 0.3]
            if len(drop):
                inserted[drop] = False
                yield StreamOp("delete", ids=id_of[drop])
        q_src = np.where(inserted)[0]
        if len(q_src) >= queries_per_step:
            q = data[rng.choice(q_src, queries_per_step, replace=False)]
            yield StreamOp("search", queries=q + rng.normal(
                scale=0.05, size=q.shape).astype(np.float32))


def msturing_ih(n_start=2000, n_final=20000, dim=32, n_ops=200,
                insert_ratio=0.9, batch=128, seed=0) -> Iterator[StreamOp]:
    """Insert-heavy growth: 90% inserts / 10% searches (MSTuring-IH)."""
    data, _ = _base_data(n_final, dim, seed)
    rng = np.random.default_rng(seed + 1)
    yield StreamOp("insert", vectors=data[:n_start])
    nxt = n_start
    for _ in range(n_ops):
        if rng.random() < insert_ratio and nxt < n_final:
            take = min(batch, n_final - nxt)
            yield StreamOp("insert", vectors=data[nxt:nxt + take])
            nxt += take
        else:
            q = data[rng.integers(0, nxt, 8)]
            yield StreamOp("search", queries=q + rng.normal(
                scale=0.05, size=q.shape).astype(np.float32))


WORKLOADS = {
    "sliding_window": sliding_window,
    "expiration_time": expiration_time,
    "clustered": clustered,
    "msturing_ih": msturing_ih,
}
