"""AdamW + cosine schedule, pure JAX.

ZeRO-style sharding falls out of FSDP: both Adam moments reuse the
parameter PartitionSpecs, so optimizer state is fully sharded over
("pod","data")×("model",) with no extra machinery.
"""
from __future__ import annotations

import math
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup: int = 200
    total_steps: int = 10_000
    moment_dtype: str = "float32"


class AdamState(NamedTuple):
    step: jax.Array
    mu: dict
    nu: dict


def init_adam(params, cfg: AdamConfig) -> AdamState:
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return AdamState(jnp.zeros((), jnp.int32),
                     jax.tree.map(zeros, params),
                     jax.tree.map(zeros, params))


def adam_specs(param_specs):
    """Optimizer-state PartitionSpecs mirroring the parameter specs."""
    from jax.sharding import PartitionSpec as P
    return AdamState(P(), param_specs, param_specs)


def schedule(step, cfg: AdamConfig):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup)
                    / jnp.maximum(cfg.total_steps - cfg.warmup, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(x.astype(jnp.float32) ** 2)
                        for x in jax.tree.leaves(tree)))


def adam_update(params, grads, state: AdamState, cfg: AdamConfig):
    """One AdamW step with global-norm clipping. Returns (params, state,
    metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = schedule(step, cfg)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m2 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v2 = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        d = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + cfg.eps)
        p2 = p.astype(jnp.float32) * (1.0 - lr * cfg.weight_decay) - lr * d
        return p2.astype(p.dtype), m2.astype(m.dtype), v2.astype(v.dtype)

    def upd_leaf(p, g, m, v):
        # (lax.map chunking was tried here and REFUTED: it added stacked
        # xs/ys buffers, +6 GB on grok — see EXPERIMENTS.md §Perf)
        return upd(p, g, m, v)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.mu)
    flat_v = jax.tree.leaves(state.nu)
    out = [upd_leaf(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, AdamState(step, new_m, new_v), {"grad_norm": gnorm, "lr": lr}
