"""Training loop: jit'd step + checkpoint/restart + metrics.

Fault tolerance: checkpoints are atomic and resumable; ``run`` restores the
newest valid checkpoint and continues from there (restart-safe), saving
asynchronously every ``ckpt_every`` steps.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as Mdl
from repro.train import optimizer as Opt
from repro.train.checkpoint import CheckpointManager
from repro.train.data import TokenPipeline


@dataclass
class TrainResult:
    losses: list
    steps: int
    restored_from: int


def make_step(cfg, adam: Opt.AdamConfig):
    @jax.jit
    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: Mdl.loss_fn(cfg, p, batch))(params)
        params, opt_state, metrics = Opt.adam_update(params, grads,
                                                     opt_state, adam)
        return params, opt_state, loss
    return step


def run(cfg, *, steps=50, batch=4, seq=64, ckpt_dir=None, ckpt_every=20,
        seed=0, adam=None, params=None) -> TrainResult:
    adam = adam or Opt.AdamConfig(lr=1e-3, warmup=10, total_steps=steps)
    key = jax.random.PRNGKey(seed)
    if params is None:
        params = Mdl.init_params(cfg, key)
    opt_state = Opt.init_adam(params, adam)
    step_fn = make_step(cfg, adam)
    pipe = TokenPipeline(cfg.vocab, batch, seq, seed=seed)

    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    start, restored = 0, -1
    if mgr is not None:
        s, tree = mgr.restore((params, opt_state))
        if s is not None:
            params, opt_state = tree
            start, restored = s, s

    losses = []
    for i in range(start, steps):
        batch_np = next(pipe)
        batch_dev = {k: jnp.asarray(v) for k, v in batch_np.items()}
        params, opt_state, loss = step_fn(params, opt_state, batch_dev)
        losses.append(float(loss))
        if mgr is not None and (i + 1) % ckpt_every == 0:
            mgr.save_async(i + 1, (params, opt_state))
    if mgr is not None:
        mgr.wait()
    pipe.close()
    return TrainResult(losses, steps, restored)
