"""Fault-tolerant checkpointing.

* sharding-aware: each leaf saved as .npy (gathered to host), manifest
  records the pytree structure; restore optionally re-shards onto any mesh
  (elastic restart on a different topology).
* atomic: writes go to ``step_XXXX.tmp`` then ``os.replace`` -> a crash
  mid-save never corrupts the latest checkpoint.
* integrity: per-leaf CRC32 in the manifest; restore falls back to the
  newest *valid* checkpoint (corrupt-checkpoint tolerance).
* async: ``save_async`` snapshots to host memory synchronously (cheap) and
  writes in a background thread (training continues).
* keep-last-k garbage collection.
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
import threading
import zlib
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    return paths, [leaf for _, leaf in flat], treedef


class CheckpointManager:
    def __init__(self, directory, keep=3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._lock = threading.Lock()
        self._threads: list = []

    # ------------------------------------------------------------------
    def save(self, step: int, tree: Any, extra: Optional[dict] = None):
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        self._write(step, host_tree, extra or {})

    def save_async(self, step: int, tree: Any, extra: Optional[dict] = None):
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)  # snapshot
        th = threading.Thread(target=self._write,
                              args=(step, host_tree, extra or {}),
                              daemon=True)
        th.start()
        self._threads.append(th)
        return th

    def wait(self):
        for th in self._threads:
            th.join()
        self._threads = []

    def _write(self, step, host_tree, extra):
        with self._lock:
            tmp = self.dir / f"step_{step:08d}.tmp"
            final = self.dir / f"step_{step:08d}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir()
            paths, leaves, treedef = _flatten_with_paths(host_tree)
            manifest = {"step": step, "extra": extra, "leaves": []}
            for i, (p, leaf) in enumerate(zip(paths, leaves)):
                fname = f"leaf_{i:05d}.npy"
                np.save(tmp / fname, leaf)
                manifest["leaves"].append({
                    "path": p, "file": fname, "shape": list(leaf.shape),
                    "dtype": str(leaf.dtype),
                    "crc": zlib.crc32(np.ascontiguousarray(leaf).tobytes()),
                })
            manifest["treedef"] = jax.tree_util.treedef_tuple  # marker only
            manifest.pop("treedef")
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            if final.exists():
                shutil.rmtree(final)
            os.replace(tmp, final)
            self._gc()

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # ------------------------------------------------------------------
    def all_steps(self):
        out = []
        for p in self.dir.glob("step_*"):
            if p.suffix == ".tmp" or not (p / "manifest.json").exists():
                continue
            out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def _validate(self, step) -> Optional[dict]:
        d = self.dir / f"step_{step:08d}"
        try:
            manifest = json.loads((d / "manifest.json").read_text())
            for leaf in manifest["leaves"]:
                arr = np.load(d / leaf["file"])
                if zlib.crc32(np.ascontiguousarray(arr).tobytes()) \
                        != leaf["crc"]:
                    return None
            return manifest
        except Exception:
            return None

    def restore(self, like: Any, step: Optional[int] = None,
                shardings: Any = None):
        """Restore into the structure of ``like``. Falls back through older
        checkpoints if the newest is corrupt. Returns (step, tree) or
        (None, None) if nothing restorable."""
        candidates = [step] if step is not None \
            else list(reversed(self.all_steps()))
        for s in candidates:
            manifest = self._validate(s)
            if manifest is None:
                continue
            d = self.dir / f"step_{s:08d}"
            leaves = [np.load(d / l["file"]) for l in manifest["leaves"]]
            treedef = jax.tree.structure(like)
            if treedef.num_leaves != len(leaves):
                continue
            tree = jax.tree.unflatten(treedef, leaves)
            if shardings is not None:
                tree = jax.tree.map(
                    lambda x, sh: jax.device_put(x, sh), tree, shardings)
            return s, tree
        return None, None
