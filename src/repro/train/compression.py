"""Distributed-optimization tricks: gradient compression + elastic remesh.

* ``ef_int8_psum`` — int8 error-feedback quantized all-reduce for the slow
  cross-pod hop: gradients are quantized per-row to int8 with the residual
  carried to the next step (1-bit-Adam-style EF), cutting cross-pod
  all-reduce bytes 4x vs fp32 / 2x vs bf16.
* ``remesh`` — elastic restart: re-shard a pytree from one mesh onto
  another (e.g. after losing a pod, continue data-parallel on the
  survivors with the same global state).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


def quantize_int8(x, axis=-1):
    """Symmetric per-row int8 quantization. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def ef_compress(x, err):
    """Error-feedback compression step: returns (decompressed, new_err)."""
    y = x.astype(jnp.float32) + err
    q, s = quantize_int8(y)
    deq = dequantize_int8(q, s)
    return deq, y - deq


def ef_int8_psum(grad, err, axis_name):
    """Quantized cross-pod all-reduce with error feedback. Call under
    shard_map with ``axis_name`` = the slow axis ("pod")."""
    deq, new_err = ef_compress(grad, err)
    return jax.lax.pmean(deq, axis_name), new_err


def make_crosspod_grad_sync(mesh, spec_tree, axis_name="pod"):
    """Wrap per-pod gradients with an EF-int8 pmean over the pod axis."""
    def sync(grads, errs):
        def one(g, e, spec):
            from repro import compat
            inner = partial(ef_int8_psum, axis_name=axis_name)
            fn = compat.shard_map(
                inner, mesh=mesh,
                in_specs=(spec, spec), out_specs=(spec, spec))
            return fn(g, e)
        flat_g, tdef = jax.tree.flatten(grads)
        flat_e = jax.tree.leaves(errs)
        flat_s = jax.tree.leaves(spec_tree, is_leaf=lambda s: isinstance(s, P))
        outs = [one(g, e, s) for g, e, s in zip(flat_g, flat_e, flat_s)]
        return (jax.tree.unflatten(tdef, [o[0] for o in outs]),
                jax.tree.unflatten(tdef, [o[1] for o in outs]))
    return sync


# ---------------------------------------------------------------------------
# Elastic re-mesh
# ---------------------------------------------------------------------------

def remesh(tree, spec_tree, new_mesh):
    """Re-shard every leaf onto ``new_mesh`` with the same logical specs —
    the state half of elastic scaling (survivor pods pick up the load).
    Specs referencing axes absent from the new mesh fall back to
    replicated on those dims."""
    new_axes = set(new_mesh.axis_names)

    def fix_spec(spec):
        out = []
        for part in spec:
            if part is None:
                out.append(None)
            elif isinstance(part, str):
                out.append(part if part in new_axes else None)
            else:
                keep = tuple(a for a in part if a in new_axes)
                out.append(keep if keep else None)
        return P(*out)

    def place(x, spec):
        return jax.device_put(np.asarray(x),
                              NamedSharding(new_mesh, fix_spec(spec)))

    # spec_tree mirrors tree's structure with P leaves; tree.map flattens
    # up to tree's leaves so each P arrives whole
    return jax.tree.map(place, tree, spec_tree)
