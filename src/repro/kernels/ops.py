"""Jit'd public wrappers over the Pallas kernels with jnp fallbacks.

On this CPU container the kernels execute in interpret mode (slow but
bit-faithful to the kernel body); production TPU builds flip
``use_pallas=True, interpret=False``. The search core calls these entry
points so the kernel path is exercised end-to-end in tests.
"""
from __future__ import annotations

import jax

from repro.kernels.l2_gather.kernel import l2_gather
from repro.kernels.l2_gather.ref import l2_gather_ref
from repro.kernels.pq_adc.kernel import pq_adc
from repro.kernels.pq_adc.ref import pq_adc_ref
from repro.kernels.row_gather.kernel import row_gather
from repro.kernels.row_gather.ref import row_gather_ref
from repro.kernels.topk_merge.kernel import topk_merge
from repro.kernels.topk_merge.ref import topk_merge_ref


def gather_l2(table, ids, queries, *, use_pallas=False, interpret=True):
    """Squared-L2 distances from gathered table rows. [B,K] fp32."""
    if use_pallas:
        return l2_gather(table, ids, queries, interpret=interpret)
    return l2_gather_ref(table, ids, queries)


def adc_gather(codes, lut, ids, *, use_pallas=False, interpret=True):
    """Asymmetric PQ distances (LUT gather) from gathered code rows —
    the code-lane twin of ``gather_l2``. [B,K] fp32, +inf invalid."""
    if use_pallas:
        return pq_adc(codes, lut, ids, interpret=interpret)
    return pq_adc_ref(codes, lut, ids)


def gather_rows(table, h2s, ids, *, use_pallas=False, interpret=True):
    """Adjacency rows for frontier ids through the device-resident
    topology cache (h2s directory -> cached row table) — the in-loop
    topology read of the fused multi-round executor. [B,W,R] int32,
    -1-sentinel rows on non-resident/idle lanes."""
    if use_pallas:
        return row_gather(table, h2s, ids, interpret=interpret)
    return row_gather_ref(table, h2s, ids)


def pool_merge(pool_d, pool_i, pool_v, new_d, new_i, *, use_pallas=False,
               interpret=True):
    """Merge candidate pool with new distances, keep best-L."""
    if use_pallas:
        return topk_merge(pool_d, pool_i, pool_v, new_d, new_i,
                          interpret=interpret)
    return topk_merge_ref(pool_d, pool_i, pool_v, new_d, new_i)
