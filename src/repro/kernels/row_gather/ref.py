"""Pure-jnp oracle for the row_gather kernel."""
import jax
import jax.numpy as jnp


@jax.jit
def row_gather_ref(table, h2s, ids):
    """table [S,R] int32 cached adjacency rows; h2s [N] int32 id->slot
    directory (-1 = non-resident); ids [B,W] int32 (-1 = idle lane) ->
    rows [B,W,R] int32, every lane of a non-resident or idle id forced
    to the -1 sentinel.

    The -1 sentinel is load-bearing twice over: downstream the fused
    round treats -1 candidates as invalid (masked to +inf before the
    merge), and the fused loop's stall detector distinguishes "id has
    no cached row" (slot < 0 with id >= 0 -> exit to host for a delta
    fetch) from "lane idle" (id < 0 -> keep going).
    """
    slot = h2s[jnp.clip(ids, 0)]
    ok = (ids >= 0) & (slot >= 0)
    rows = table[jnp.clip(slot, 0)]
    return jnp.where(ok[..., None], rows, -1)
