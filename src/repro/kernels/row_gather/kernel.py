"""Masked adjacency-row gather Pallas TPU kernel.

The in-loop topology read of the fused multi-round executor: for each
query, resolve the beam's frontier ids through the device-resident
topology cache (h2s id->slot directory, then the cached row table) and
emit the adjacency rows, with the -1 sentinel on every lane whose id is
idle (< 0) or not resident (h2s[id] < 0). The sentinel is what lets the
``lax.while_loop`` body detect a topology-cache miss without a host
round-trip: a non-resident id in the frontier surfaces as an all--1 row
*plus* a cleared residency bit, and the loop exits to the host for the
delta fetch.

TPU-native shape (same house idiom as ``l2_gather``): frontier ids are
scalar-prefetched (SMEM), each lane chains two DMAs — one element of the
h2s directory HBM→SMEM to find the slot, then the slot's row HBM→VMEM —
and the masking runs vectorized over the gathered [W, R] block. Directory
and row table stay in ANY/HBM; only W rows (W·R·4 bytes) touch VMEM.
Validated in interpret mode against ref.py (CPU container).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(ids_ref, idv_ref, h2s_ref, table_ref, out_ref,
            rows_ref, slots_ref, slot1_ref, sem):
    W = out_ref.shape[1]
    b = pl.program_id(0)

    def fetch(w, _):
        idx = jnp.maximum(ids_ref[b, w], 0)    # clamp idle lanes
        cp = pltpu.make_async_copy(h2s_ref.at[pl.ds(idx, 1)],
                                   slot1_ref.at[pl.ds(0, 1)], sem)
        cp.start()
        cp.wait()
        slot = slot1_ref[0]
        slots_ref[0, w] = slot
        cp2 = pltpu.make_async_copy(
            table_ref.at[pl.ds(jnp.maximum(slot, 0), 1), :],
            rows_ref.at[pl.ds(w, 1), :], sem)
        cp2.start()
        cp2.wait()
        return 0

    jax.lax.fori_loop(0, W, fetch, 0)
    rows = rows_ref[...]                       # [W, R] VMEM
    ok = (idv_ref[0] >= 0) & (slots_ref[0] >= 0)
    out_ref[0] = jnp.where(ok[:, None], rows, -1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def row_gather(table, h2s, ids, *, interpret=True):
    """table [S, R] int32 cached rows; h2s [N] int32 id->slot (-1 =
    non-resident); ids [B, W] int32 (-1 = idle lane) -> [B, W, R] int32
    adjacency rows, -1-filled on non-resident/idle lanes."""
    B, W = ids.shape
    S, R = table.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, W), lambda b, ids: (b, 0)),      # valid mask
            pl.BlockSpec(memory_space=pltpu.ANY),             # h2s HBM
            pl.BlockSpec(memory_space=pltpu.ANY),             # table HBM
        ],
        out_specs=pl.BlockSpec((1, W, R), lambda b, ids: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((W, R), jnp.int32),
            pltpu.VMEM((1, W), jnp.int32),
            pltpu.SMEM((1,), jnp.int32),
            pltpu.SemaphoreType.DMA,
        ],
    )
    ids = ids.astype(jnp.int32)
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, W, R), jnp.int32),
        interpret=interpret,
    )(ids, ids, h2s.astype(jnp.int32), table.astype(jnp.int32))
