"""Fused row-gather + L2-distance Pallas TPU kernel.

The inner loop of SVFusion's beam search: for each query, fetch the K
neighbor vectors named by the mapping table and compute squared-L2
distances. On GPU this is a warp-per-row gather; the TPU-native shape
(DESIGN.md §2) is: neighbor ids scalar-prefetched (SMEM), row DMAs
HBM→VMEM per id, then one [K,D]·[D] contraction on the MXU via the
||x||² − 2·x·q + ||q||² expansion.

Grid: one step per query. Table stays in ANY/HBM; only the K gathered rows
ever touch VMEM (K·D·4 bytes, e.g. 64×128×4 = 32 KiB ≪ 16 MiB VMEM).
Validated in interpret mode against ref.py (CPU container); targets
pl.pallas_call + BlockSpec for real TPU lowering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(ids_ref, q_ref, table_ref, out_ref, rows_ref, sem):
    K = out_ref.shape[1]
    b = pl.program_id(0)

    def fetch(k, _):
        idx = ids_ref[b, k]
        cp = pltpu.make_async_copy(table_ref.at[pl.ds(idx, 1), :],
                                   rows_ref.at[pl.ds(k, 1), :], sem)
        cp.start()
        cp.wait()
        return 0

    jax.lax.fori_loop(0, K, fetch, 0)
    x = rows_ref[...]                         # [K, D] VMEM
    q = q_ref[0]                              # [D]
    x2 = jnp.sum(x * x, axis=-1)
    q2 = jnp.sum(q * q)
    xq = jnp.dot(x, q, preferred_element_type=jnp.float32)   # MXU
    out_ref[0] = x2 - 2.0 * xq + q2


@functools.partial(jax.jit, static_argnames=("interpret",))
def l2_gather(table, ids, queries, *, interpret=True):
    """table [N, D] f32; ids [B, K] int32; queries [B, D] f32 -> [B, K]."""
    B, K = ids.shape
    N, D = table.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, D), lambda b, ids: (b, 0)),          # query row
            pl.BlockSpec(memory_space=pltpu.ANY),                 # table HBM
        ],
        out_specs=pl.BlockSpec((1, K), lambda b, ids: (b, 0)),
        scratch_shapes=[
            pltpu.VMEM((K, D), jnp.float32),
            pltpu.SemaphoreType.DMA,
        ],
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K), jnp.float32),
        interpret=interpret,
    )(ids, queries.astype(jnp.float32), table.astype(jnp.float32))
