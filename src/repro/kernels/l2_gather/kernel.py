"""Fused row-gather + L2-distance Pallas TPU kernel.

The inner loop of SVFusion's hop-batched frontier executor: for each
query, fetch the K neighbor vectors named by the id matrix and compute
squared-L2 distances. On GPU this is a warp-per-row gather; the
TPU-native shape (DESIGN.md §2) is: neighbor ids scalar-prefetched
(SMEM), row DMAs HBM→VMEM per id, then one [K,D]·[D] contraction on the
MXU via the ||x||² − 2·x·q + ||q||² expansion.

The executor feeds the batched (Q, beam·degree) id matrix of a whole
expansion round, so K runs to beam·degree and ids may carry invalid
lanes (-1: padded beam slots, pruned edges). Invalid ids are clamped for
the DMA and their distances forced to +inf in-kernel — indexing the
table at -1 is never attempted.

Grid: one step per query. Table stays in ANY/HBM; only the K gathered
rows ever touch VMEM (K·D·4 bytes, e.g. 128×128×4 = 64 KiB ≪ 16 MiB
VMEM). Validated in interpret mode against ref.py (CPU container);
targets pl.pallas_call + BlockSpec for real TPU lowering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(ids_ref, q_ref, idv_ref, table_ref, out_ref, rows_ref, sem):
    K = out_ref.shape[1]
    b = pl.program_id(0)

    def fetch(k, _):
        idx = jnp.maximum(ids_ref[b, k], 0)    # clamp invalid lanes
        cp = pltpu.make_async_copy(table_ref.at[pl.ds(idx, 1), :],
                                   rows_ref.at[pl.ds(k, 1), :], sem)
        cp.start()
        cp.wait()
        return 0

    jax.lax.fori_loop(0, K, fetch, 0)
    x = rows_ref[...]                         # [K, D] VMEM
    q = q_ref[0]                              # [D]
    x2 = jnp.sum(x * x, axis=-1)
    q2 = jnp.sum(q * q)
    xq = jnp.dot(x, q, preferred_element_type=jnp.float32)   # MXU
    d = x2 - 2.0 * xq + q2
    out_ref[0] = jnp.where(idv_ref[0] >= 0, d, jnp.inf)


@functools.partial(jax.jit, static_argnames=("interpret",))
def l2_gather(table, ids, queries, *, interpret=True):
    """table [N, D] f32; ids [B, K] int32 (-1 = invalid lane);
    queries [B, D] f32 -> [B, K] fp32, +inf on invalid lanes."""
    B, K = ids.shape
    N, D = table.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, D), lambda b, ids: (b, 0)),          # query row
            pl.BlockSpec((1, K), lambda b, ids: (b, 0)),          # valid mask
            pl.BlockSpec(memory_space=pltpu.ANY),                 # table HBM
        ],
        out_specs=pl.BlockSpec((1, K), lambda b, ids: (b, 0)),
        scratch_shapes=[
            pltpu.VMEM((K, D), jnp.float32),
            pltpu.SemaphoreType.DMA,
        ],
    )
    ids = ids.astype(jnp.int32)
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K), jnp.float32),
        interpret=interpret,
    )(ids, queries.astype(jnp.float32), ids, table.astype(jnp.float32))
