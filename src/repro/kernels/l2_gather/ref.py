"""Pure-jnp oracle for the l2_gather kernel."""
import jax
import jax.numpy as jnp


@jax.jit
def l2_gather_ref(table, ids, queries):
    """table [N,D]; ids [B,K]; queries [B,D] -> squared L2 dists [B,K]."""
    x = table[ids]                                   # [B, K, D]
    d = x - queries[:, None, :].astype(table.dtype)
    return jnp.sum(d.astype(jnp.float32) ** 2, axis=-1)
