"""Pure-jnp oracle for the l2_gather kernel."""
import jax
import jax.numpy as jnp


@jax.jit
def l2_gather_ref(table, ids, queries):
    """table [N,D]; ids [B,K] (-1 = invalid lane); queries [B,D] ->
    squared L2 dists [B,K] fp32, +inf on invalid lanes.

    K is arbitrary — the frontier executor passes the batched
    (Q, beam*degree) id matrix of a whole expansion round.
    """
    x = table[jnp.clip(ids, 0)]                      # [B, K, D]
    d = x - queries[:, None, :].astype(table.dtype)
    out = jnp.sum(d.astype(jnp.float32) ** 2, axis=-1)
    return jnp.where(ids >= 0, out, jnp.inf)
