"""Pure-jnp oracle for the topk_merge kernel."""
import jax
import jax.numpy as jnp


@jax.jit
def topk_merge_ref(pool_d, pool_i, pool_v, new_d, new_i):
    d = jnp.concatenate([pool_d, new_d], axis=1).astype(jnp.float32)
    i = jnp.concatenate([pool_i, new_i], axis=1).astype(jnp.int32)
    v = jnp.concatenate([pool_v, jnp.zeros_like(new_i, bool)], axis=1)
    L = pool_d.shape[1]
    # sort by (distance, id) — deterministic total order matching the kernel
    order = jnp.lexsort((i, d), axis=1)
    d2 = jnp.take_along_axis(d, order, axis=1)[:, :L]
    i2 = jnp.take_along_axis(i, order, axis=1)[:, :L]
    v2 = jnp.take_along_axis(v, order, axis=1)[:, :L]
    return d2, i2, v2
