"""Candidate-pool merge Pallas TPU kernel (bitonic partial sort).

Merges the L-entry candidate pool with R freshly computed neighbor
distances and keeps the best L — the per-iteration pool update of
Algorithm 1. A GPU implementation leans on warp shuffles; the TPU version
is a data-parallel bitonic network over the padded [L+R] lane vector in
VMEM (compare-exchange via strided reshapes on the VPU), carrying
(distance, id, visited) triples through the permutation.

Validated in interpret mode against ref.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bitonic(d, i, v):
    """Full ascending bitonic sort of (d, i, v) rows [B, P], P = 2^m.

    The partner exchange (lane ``j ^ stride``) is a strided reshape +
    reverse, not a gather: lane j decomposes as (block, bit, offset) with
    ``bit = (j // stride) & 1``, and XOR-ing the stride flips exactly that
    axis. XLA compiles this in linear time, where the equivalent
    take_along_axis network blows up compile superlinearly (and gathers
    are the slow path on the VPU anyway).
    """
    B, P = d.shape
    m = P.bit_length() - 1
    idx = jax.lax.broadcasted_iota(jnp.int32, d.shape, 1)
    for stage in range(1, m + 1):
        up = ((idx >> stage) & 1) == 0              # ascending block?
        for sub in range(stage, 0, -1):
            stride = 1 << (sub - 1)

            def partner(x):
                y = x.reshape(B, P // (2 * stride), 2, stride)
                return y[:, :, ::-1, :].reshape(B, P)

            pd, pi, pv = partner(d), partner(i), partner(v)
            is_lo = (idx & stride) == 0
            keep_self = jnp.where(up, (d < pd) | ((d == pd) & (i <= pi)),
                                  (d > pd) | ((d == pd) & (i >= pi)))
            keep_self = jnp.where(is_lo, keep_self, ~keep_self)
            d = jnp.where(keep_self, d, pd)
            i = jnp.where(keep_self, i, pi)
            v = jnp.where(keep_self, v, pv)
    return d, i, v


def _kernel(pool_d_ref, pool_i_ref, pool_v_ref, new_d_ref, new_i_ref,
            out_d_ref, out_i_ref, out_v_ref):
    L = pool_d_ref.shape[1]
    R = new_d_ref.shape[1]
    P = 1 << (L + R - 1).bit_length()
    pad = P - (L + R)
    d = jnp.concatenate([pool_d_ref[...], new_d_ref[...],
                         jnp.full((1, pad), jnp.inf, jnp.float32)], axis=1)
    i = jnp.concatenate([pool_i_ref[...], new_i_ref[...],
                         jnp.full((1, pad), -1, jnp.int32)], axis=1)
    v = jnp.concatenate([pool_v_ref[...].astype(jnp.int32),
                         jnp.zeros((1, R + pad), jnp.int32)], axis=1)
    d, i, v = _bitonic(d, i, v)
    out_d_ref[...] = d[:, :L]
    out_i_ref[...] = i[:, :L]
    out_v_ref[...] = v[:, :L]


@functools.partial(jax.jit, static_argnames=("interpret",))
def topk_merge(pool_d, pool_i, pool_v, new_d, new_i, *, interpret=True):
    """Merge pools. pool_* [B, L]; new_* [B, R] -> best-L (d, i, visited)."""
    B, L = pool_d.shape
    R = new_d.shape[1]
    specs_in = [pl.BlockSpec((1, L), lambda b: (b, 0)),
                pl.BlockSpec((1, L), lambda b: (b, 0)),
                pl.BlockSpec((1, L), lambda b: (b, 0)),
                pl.BlockSpec((1, R), lambda b: (b, 0)),
                pl.BlockSpec((1, R), lambda b: (b, 0))]
    specs_out = [pl.BlockSpec((1, L), lambda b: (b, 0))] * 3
    out_d, out_i, out_v = pl.pallas_call(
        _kernel,
        grid=(B,),
        in_specs=specs_in,
        out_specs=specs_out,
        out_shape=[jax.ShapeDtypeStruct((B, L), jnp.float32),
                   jax.ShapeDtypeStruct((B, L), jnp.int32),
                   jax.ShapeDtypeStruct((B, L), jnp.int32)],
        interpret=interpret,
    )(pool_d.astype(jnp.float32), pool_i.astype(jnp.int32),
      pool_v.astype(jnp.int32), new_d.astype(jnp.float32),
      new_i.astype(jnp.int32))
    return out_d, out_i, out_v.astype(bool)
