"""Pure-jnp oracle for the pq_adc kernel."""
import jax
import jax.numpy as jnp


@jax.jit
def pq_adc_ref(codes, lut, ids):
    """codes [N, m] uint8; lut [B, m, K] fp32 per-query ADC tables;
    ids [B, C] (-1 = invalid lane) -> asymmetric distances [B, C] fp32,
    +inf on invalid lanes.

    ``d[b, c] = Σ_s lut[b, s, codes[ids[b, c], s]]`` — the LUT-gather form
    of the asymmetric PQ distance. C is arbitrary: the frontier executor
    passes the batched (Q, beam·degree) id matrix of a whole expansion
    round, same contract as ``l2_gather_ref``.
    """
    c = codes[jnp.clip(ids, 0)].astype(jnp.int32)        # [B, C, m]
    d = jnp.take_along_axis(lut, c.transpose(0, 2, 1), axis=2)  # [B, m, C]
    out = jnp.sum(d, axis=1)
    return jnp.where(ids >= 0, out, jnp.inf)
