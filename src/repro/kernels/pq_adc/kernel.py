"""Fused code-gather + ADC-scan Pallas TPU kernel.

The inner loop of the PQ code lane (quant.py): for each query, fetch the
C candidate code rows named by the frontier executor's id matrix and
accumulate the asymmetric distance from the query's precomputed lookup
table — ``d[c] = Σ_s lut[s, codes[c, s]]``. The structure mirrors
``l2_gather``: neighbor ids scalar-prefetched (SMEM), per-id row DMAs
HBM→VMEM, then one dense contraction — except the gathered rows are m
uint8 codes instead of D fp32 lanes (D·4/m less DMA traffic, the whole
point of the lane), and the "distance" is a LUT gather, realized as a
one-hot [C, m·K] × [m·K] contraction so it lands on the MXU instead of a
serialized scalar gather loop.

Ids may carry invalid lanes (-1: padded beam slots, pruned edges):
clamped for the DMA, forced to +inf in-kernel — the code table is never
indexed at -1, same contract as l2_gather.

Grid: one step per query. The code table stays in ANY/HBM; only the C
gathered rows touch VMEM (C·m bytes — for C=512, m=16 that is 8 KiB vs
the exact lane's C·D·4). uint8 rows tile at (32, 128); interpret mode
(this CPU container) is shape-agnostic. Validated against ref.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


_TILE_C = 128   # candidate rows per one-hot contraction tile: bounds the
#                 [TILE_C, m·K] fp32 intermediate at 2 MiB for m=16, K=256
#                 (the scale preset's C = beam·R = 1024 would otherwise
#                 materialize a 16 MiB tensor — the whole VMEM budget)


def _kernel(ids_ref, lut_ref, idv_ref, codes_ref, out_ref, rows_ref, sem):
    C = out_ref.shape[1]
    b = pl.program_id(0)

    def fetch(c, _):
        idx = jnp.maximum(ids_ref[b, c], 0)    # clamp invalid lanes
        cp = pltpu.make_async_copy(codes_ref.at[pl.ds(idx, 1), :],
                                   rows_ref.at[pl.ds(c, 1), :], sem)
        cp.start()
        cp.wait()
        return 0

    jax.lax.fori_loop(0, C, fetch, 0)
    lut = lut_ref[0]                                      # [m, K]
    K = lut.shape[1]
    lut_flat = lut.reshape(-1)
    iota_k = jax.lax.broadcasted_iota(jnp.int32, (1, 1, K), 2)
    tc = min(C, _TILE_C)    # wrapper pads C to a multiple of _TILE_C

    def tile(t, _):
        # LUT gather as a one-hot contraction: flat index s·K + code
        # selects lut[s, code]; the [tc, m·K] × [m·K] product runs on
        # the MXU, one bounded tile of candidates at a time
        cod = rows_ref[pl.ds(t * tc, tc), :].astype(jnp.int32)  # [tc, m]
        onehot = (cod[:, :, None] == iota_k).astype(jnp.float32)
        d = jnp.dot(onehot.reshape(tc, -1), lut_flat,
                    preferred_element_type=jnp.float32)   # [tc]
        out_ref[0, pl.ds(t * tc, tc)] = jnp.where(
            idv_ref[0, pl.ds(t * tc, tc)] >= 0, d, jnp.inf)
        return 0

    jax.lax.fori_loop(0, C // tc, tile, 0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def pq_adc(codes, lut, ids, *, interpret=True):
    """codes [N, m] uint8; lut [B, m, K] f32; ids [B, C] int32 (-1 =
    invalid lane) -> ADC distances [B, C] fp32, +inf on invalid lanes."""
    B, C0 = ids.shape
    N, m = codes.shape
    K = lut.shape[2]
    # pad the lane axis to a whole number of contraction tiles (-1 lanes
    # come back +inf and are sliced off below)
    C = -(-C0 // min(C0, _TILE_C)) * min(C0, _TILE_C)
    if C != C0:
        ids = jnp.concatenate(
            [ids, jnp.full((B, C - C0), -1, ids.dtype)], axis=1)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, m, K), lambda b, ids: (b, 0, 0)),    # ADC LUT
            pl.BlockSpec((1, C), lambda b, ids: (b, 0)),          # valid mask
            pl.BlockSpec(memory_space=pltpu.ANY),                 # codes HBM
        ],
        out_specs=pl.BlockSpec((1, C), lambda b, ids: (b, 0)),
        scratch_shapes=[
            pltpu.VMEM((C, m), jnp.uint8),
            pltpu.SemaphoreType.DMA,
        ],
    )
    ids = ids.astype(jnp.int32)
    out = pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, C), jnp.float32),
        interpret=interpret,
    )(ids, lut.astype(jnp.float32), ids, codes.astype(jnp.uint8))
    return out[:, :C0]
