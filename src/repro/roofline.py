"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

Three terms per (arch × shape × mesh), all in seconds per step:

    compute    = HLO_FLOPs_per_device (scan-corrected) / 197 TF/s bf16
    memory     = HLO_bytes_per_device (scan-corrected) / 819 GB/s HBM
    collective = collective_bytes_per_device (corrected) / 50 GB/s ICI

cost_analysis is per-partition (per-device) on the SPMD module, so terms
are per-chip directly. ``roofline_fraction`` = ideal compute time of the
*useful* MODEL_FLOPS divided by the bounding term — the fraction of peak
the compiled program could reach if it hit the dominant roof.

CPU-backend caveat (recorded per row): XLA-CPU emulates bf16 in fp32, so
``bytes``/``temp`` overstate bf16 traffic by up to 2x vs real TPU lowering.
"""
from __future__ import annotations

import json
import pathlib

PEAK_FLOPS = 197e12        # v5e bf16
HBM_BW = 819e9             # v5e HBM
ICI_BW = 50e9              # effective per-chip ICI

RESULTS = pathlib.Path(__file__).resolve().parents[2] / "results" / "dryrun"


def load_cells(mesh="pod256"):
    cells = {}
    d = RESULTS / mesh
    if not d.exists():
        return cells
    for p in sorted(d.glob("*.json")):
        r = json.loads(p.read_text())
        if r.get("ok"):
            cells[(r["arch"], r["shape"])] = r
    return cells


def terms(rec) -> dict:
    chips = rec["n_chips"]
    flops = rec.get("flops_corrected", rec["flops"])
    byts = rec.get("bytes_corrected", rec["bytes_accessed"])
    coll = rec.get("coll_corrected",
                   rec["collectives"]["total_bytes"])
    t_comp = flops / PEAK_FLOPS
    # memory term from BUFFER TRAFFIC (args read + outputs written + temps
    # written-and-read once) — full-program totals, no scan correction
    # needed. cost_analysis "bytes accessed" ignores fusion and wildly
    # overstates HBM traffic; it is kept as an upper bound column.
    mem = rec["memory"]
    traffic = (mem["argument_bytes"] + mem["output_bytes"]
               + 2 * mem["temp_bytes"])
    t_mem = traffic / HBM_BW
    t_mem_hlo_upper = byts / HBM_BW
    t_coll = coll / ICI_BW
    bound = max(t_comp, t_mem, t_coll)
    dominant = ("compute" if bound == t_comp
                else "memory" if bound == t_mem else "collective")
    model = rec.get("model_flops", 0.0)
    ideal = model / chips / PEAK_FLOPS
    # HLO cost analysis cannot see while-loop trip counts, so useful_ratio
    # is undefined for the beam-search cells (MODEL_FLOPS is analytical)
    svf = rec["arch"].startswith("svfusion")
    return {
        "arch": rec["arch"], "shape": rec["shape"], "chips": chips,
        "t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
        "t_mem_hlo_upper_s": t_mem_hlo_upper,
        "dominant": dominant,
        "model_flops": model,
        "useful_ratio": "n/a" if svf else
        ((model / (flops * chips)) if flops else 0.0),
        "roofline_fraction": (ideal / bound) if bound else 0.0,
        "temp_gb": rec["memory"]["temp_bytes"] / 1e9,
        "arg_gb": rec["memory"]["argument_bytes"] / 1e9,
        "notes": rec.get("notes", ""),
    }


def what_would_help(t) -> str:
    if t["dominant"] == "collective":
        return ("cut collective bytes: larger per-hop fusion, reduce-scatter"
                " instead of all-gather+slice, or keep weights resident"
                " (less FSDP regathering)")
    if t["dominant"] == "memory":
        return ("raise arithmetic intensity: fuse attention (flash kernel),"
                " larger matmul tiles, bf16 end-to-end, fewer remat"
                " round-trips")
    if t["useful_ratio"] < 0.6:
        return ("recover wasted compute: remat policy, causal-block skip,"
                " unpadded head sharding")
    return "near compute roof: only kernel-level MXU utilization remains"


def table(mesh="pod256") -> list[dict]:
    return [terms(r) for r in load_cells(mesh).values()]


def markdown_table(rows, cols, header=None) -> str:
    out = ["| " + " | ".join(header or cols) + " |",
           "|" + "---|" * len(cols)]
    for r in rows:
        cells = []
        for c in cols:
            v = r[c]
            if isinstance(v, float):
                if "ratio" in c or "fraction" in c:
                    cells.append(f"{v:.3f}")
                elif v and (abs(v) < 1e-3 or abs(v) > 1e5):
                    cells.append(f"{v:.2e}")
                else:
                    cells.append(f"{v:.3f}")
            else:
                cells.append(str(v))
        out.append("| " + " | ".join(cells) + " |")
    return "\n".join(out)


def main():
    for mesh in ("pod256", "pod512"):
        rows = table(mesh)
        rows.sort(key=lambda r: (r["arch"], r["shape"]))
        print(f"\n## {mesh}\n")
        print(markdown_table(rows, ["arch", "shape", "t_compute_s",
                                    "t_memory_s", "t_collective_s",
                                    "dominant", "useful_ratio",
                                    "roofline_fraction", "temp_gb"]))


if __name__ == "__main__":
    main()
