"""Retrieval-augmented serving: the paper's motivating workload.

The LM produces query embeddings (mean-pooled token embeddings — the
standard cheap dual-encoder stand-in); SVFusion retrieves fresh context
ids; retrieved token chunks are prepended to the prompt. New documents
stream into the index online, so retrieval reflects inserts made seconds
earlier (index freshness, paper §1).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import EngineConfig, SVFusionEngine
from repro.models import model as Mdl


@dataclass
class Doc:
    doc_id: int
    tokens: np.ndarray      # [T] int32


class RAGPipeline:
    def __init__(self, cfg, params, index_cfg: EngineConfig, dim=None):
        self.cfg = cfg
        self.params = params
        self.dim = dim or cfg.d_model
        seed_vecs = np.random.default_rng(0).normal(
            size=(max(256, index_cfg.degree * 4), self.dim)).astype(np.float32)
        self.index = SVFusionEngine(seed_vecs, index_cfg)
        self.docs: dict[int, Doc] = {}
        self._embed = jax.jit(self._embed_fn)

    def _embed_fn(self, tokens):
        emb = Mdl.embed_tokens(self.params["tok"], tokens, self.cfg,
                               jnp.bfloat16)
        return jnp.mean(emb.astype(jnp.float32), axis=1)

    def embed(self, tokens: np.ndarray) -> np.ndarray:
        return np.asarray(self._embed(jnp.asarray(tokens, jnp.int32)))

    # ------------------------------------------------------------------
    def ingest(self, docs: list[Doc]):
        """Stream new documents into the live index."""
        toks = np.stack([d.tokens for d in docs])
        vecs = self.embed(toks)
        ids = self.index.insert(vecs)
        for i, d in zip(ids, docs):
            self.docs[int(i)] = d
        return ids

    def evict(self, ids):
        self.index.delete(np.asarray(ids))
        for i in ids:
            self.docs.pop(int(i), None)

    def retrieve(self, prompt_tokens: np.ndarray, k=4) -> list[Doc]:
        return self.retrieve_batch(prompt_tokens[None, :], k)[0]

    def retrieve_batch(self, prompt_tokens: np.ndarray,
                       k=4) -> list[list[Doc]]:
        """Batched retrieval for a [B, T] prompt batch: every prompt rides
        the same hop-batched frontier executor dispatches (one jitted
        round per beam for the whole batch, not per prompt), which is how
        the serving tier amortizes device round-trips under load."""
        q = self.embed(prompt_tokens)
        ids, _ = self.index.search(q)
        return [[self.docs[int(i)] for i in row[:k] if int(i) in self.docs]
                for row in ids]

    def retrieve_many(self, prompt_batches: list, k=4) -> list:
        """Pipelined retrieval for independently-arriving prompt batches:
        every batch is submitted to the engine's cross-query coalescing
        scheduler up front, so requests that arrive within the adaptive
        window share ONE executor invocation (embedding of batch i+1
        overlaps the in-flight search of batch i as a bonus). Returns one
        ``retrieve_batch``-shaped list per input batch."""
        futs = [self.index.submit_search(self.embed(toks))
                for toks in prompt_batches]
        out = []
        for fut in futs:
            ids, _ = fut.result()
            out.append([[self.docs[int(i)] for i in row[:k]
                         if int(i) in self.docs] for row in ids])
        return out

    def augment(self, prompt_tokens: np.ndarray, k=4, budget=128):
        """Prepend retrieved chunks (truncated to the context budget)."""
        docs = self.retrieve(prompt_tokens, k)
        ctx = [d.tokens for d in docs]
        flat = np.concatenate(ctx)[:budget] if ctx else np.zeros(0, np.int32)
        return np.concatenate([flat.astype(np.int32), prompt_tokens])
