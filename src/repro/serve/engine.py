"""LM serving engine: continuous batching + deadline-aware scheduling +
straggler mitigation.

Continuous batching: a fixed pool of decode slots; finished/empty slots are
refilled from the admission queue each tick (no head-of-line blocking on
long generations). Straggler mitigation: per-tick deadline — if a tick
exceeds ``straggler_factor`` × the EWMA tick time, the engine flags the
slot batch, re-enqueues its requests and re-dispatches (on real pods:
re-route to a healthy replica; here: re-dispatch after recompile/jitter).
Elastic hook: ``on_remesh`` lets the driver swap shardings after topology
changes.
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as Mdl


@dataclass
class Request:
    rid: int
    prompt: np.ndarray            # [S] int32
    max_new: int = 16
    submitted: float = field(default_factory=time.perf_counter)
    tokens: list = field(default_factory=list)
    done: bool = False
    finished_at: float = 0.0
    retries: int = 0


class ServeEngine:
    """Batched incremental decoding over the model zoo."""

    def __init__(self, cfg, params, *, slots=8, max_len=256,
                 straggler_factor=8.0, max_retries=1):
        self.cfg = cfg.replace(remat_policy="none")
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.straggler_factor = straggler_factor
        self.max_retries = max_retries
        self.admit_q: "queue.Queue[Request]" = queue.Queue()
        self.active: list[Optional[Request]] = [None] * slots
        self.completed: list[Request] = []
        self.tick_ewma = None
        self.stragglers = 0

        self._decode = jax.jit(
            lambda p, c, t: Mdl.decode_step(self.cfg, p, c, t))
        self.cache = Mdl.init_cache(self.cfg, slots, max_len)
        # per-lane positions (continuous batching): a fresh request restarts
        # its lane at position 0; stale cache beyond lane_len is never
        # unmasked because attention caps at the lane's own length
        self.lane_len = np.zeros(slots, np.int32)
        self.tokens = np.zeros((slots, 1), np.int32)

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        self.admit_q.put(req)

    def _admit(self):
        for s in range(self.slots):
            if self.active[s] is not None:
                continue
            try:
                req = self.admit_q.get_nowait()
            except queue.Empty:
                return
            self.active[s] = req
            # prefill-by-decode for simplicity at serving scale: feed prompt
            # tokens one per tick (batch prefill is used by the RAG driver)
            req._feed = list(req.prompt)
            self.lane_len[s] = 0
            self.tokens[s, 0] = req._feed.pop(0)

    def _observe_tick(self, dt) -> bool:
        """Classify a tick duration against the EWMA and fold it in.

        The straggler comparison uses the EWMA *before* this tick, and a
        flagged tick never updates the EWMA: a straggler folded into its
        own threshold inflates it, making the next straggler invisible
        (two back-to-back slow ticks would count as one).
        """
        ewma = self.tick_ewma
        if ewma is not None and dt > self.straggler_factor * ewma:
            self.stragglers += 1
            return True
        self.tick_ewma = dt if ewma is None else 0.9 * ewma + 0.1 * dt
        return False

    def tick(self):
        """One decode step for the whole slot pool. Returns #active."""
        self._admit()
        if all(r is None for r in self.active):
            return 0
        t0 = time.perf_counter()
        self.cache = dict(self.cache, len=jnp.asarray(self.lane_len))
        logits, self.cache = self._decode(self.params, self.cache,
                                          jnp.asarray(self.tokens))
        active_mask = np.array([r is not None for r in self.active])
        self.lane_len = np.where(active_mask,
                                 np.minimum(self.lane_len + 1,
                                            self.max_len - 1),
                                 self.lane_len)
        logits = np.asarray(logits[:, 0])
        dt = time.perf_counter() - t0

        # straggler check (pod-level analogue: re-dispatch to replica)
        if self._observe_tick(dt):
            for s, req in enumerate(self.active):
                if req is not None and req.retries < self.max_retries:
                    req.retries += 1
                    req._feed = list(req.prompt)
                    req.tokens = []
                    self.admit_q.put(req)
                    self.active[s] = None
            return sum(r is not None for r in self.active)

        for s, req in enumerate(self.active):
            if req is None:
                continue
            if req._feed:                      # still feeding the prompt
                self.tokens[s, 0] = req._feed.pop(0)
                continue
            nxt = int(np.argmax(logits[s]))
            req.tokens.append(nxt)
            self.tokens[s, 0] = nxt
            if len(req.tokens) >= req.max_new:
                req.done = True
                req.finished_at = time.perf_counter()
                self.completed.append(req)
                self.active[s] = None
        return sum(r is not None for r in self.active)

    def run_until_drained(self, max_ticks=10_000):
        ticks = 0
        while (not self.admit_q.empty() or any(
                r is not None for r in self.active)) and ticks < max_ticks:
            self.tick()
            ticks += 1
        return ticks
