"""Multi-version mechanism (paper §5.3).

A consolidation (or large repair) runs on an immutable snapshot G_t0 while
foreground inserts/deletes/searches continue on the active graph G_t1.
At completion the background result G'_t0 is merged:

* **Incremental subgraph appending** — vertices inserted after the snapshot
  (id >= snapshot_n) keep their active-graph rows verbatim.
* **Reverse-edge integration** — reverse-edge triplets (v, v_new, d) logged
  during the window are re-applied onto the consolidated rows of old
  vertices.
* deletions that happened during the window stay authoritative (the alive
  bitset is taken from the active graph).

A bounded-version policy (engine.py) defers new snapshots once the limit is
reached.

The same protocol is ported to the disk tier (``TieredSnapshot`` /
``snapshot_tiered`` / ``merge_consolidated_tiered``): the snapshot freezes
only the per-id metadata that consolidation depends on — adjacency rows
and the alive bitset, a few bytes per id — while vectors, which are
immutable per id, keep streaming from the live store. Consolidation
(``update.consolidate_tiered``) then runs entirely off the update stream:
inserts/deletes continue on the active log and the merge below publishes
in one short critical section, so consolidation blocks neither searches
nor updates.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import GraphState, IndexState
from repro.core.build import compute_e_in
from repro.core.update import (RevLog, _reverse_edge_scatter,
                               reverse_edge_rows_host)


@jax.jit
def merge_consolidated(consolidated: IndexState, active: IndexState,
                       snapshot_n, rev_log: RevLog) -> IndexState:
    """Merge background-consolidated snapshot into the active state."""
    gc, ga = consolidated.graph, active.graph
    N = ga.capacity
    is_new = jnp.arange(N, dtype=jnp.int32) >= snapshot_n

    # old rows from the consolidated graph, new rows appended from active
    nbrs = jnp.where(is_new[:, None], ga.nbrs, gc.nbrs)
    graph = ga._replace(nbrs=nbrs)

    # re-apply window reverse edges onto consolidated old rows
    apply_mask = (rev_log.v >= 0) & (rev_log.v < snapshot_n) \
        & graph.alive[jnp.clip(rev_log.v, 0)] \
        & graph.alive[jnp.clip(rev_log.v_new, 0)]
    targets = jnp.where(apply_mask, rev_log.v, -1)
    nbrs = _reverse_edge_scatter(graph, targets, rev_log.v_new, rev_log.d)
    graph = graph._replace(nbrs=nbrs,
                           e_in=compute_e_in(nbrs, N),
                           version=jnp.maximum(ga.version, gc.version) + 1)
    return IndexState(graph, active.cache, active.stats)


def empty_rev_log() -> RevLog:
    z = jnp.zeros((0,), jnp.int32)
    return RevLog(z, z, jnp.zeros((0,), jnp.float32))


def concat_rev_logs(logs) -> RevLog:
    logs = [l for l in logs if l.v.shape[0]]
    if not logs:
        return empty_rev_log()
    return RevLog(jnp.concatenate([l.v for l in logs]),
                  jnp.concatenate([l.v_new for l in logs]),
                  jnp.concatenate([l.d for l in logs]))


# ---------------------------------------------------------------------------
# Disk-tier port: snapshot / merge for the streaming consolidation
# ---------------------------------------------------------------------------

class TieredSnapshot(NamedTuple):
    """Frozen view of the disk-tier graph metadata at snapshot time.
    Vectors are immutable per id and deliberately NOT copied. Filter
    attributes (``tiers.AttributeStore``) are likewise per-id immutable
    once written by their INSERT op, so consolidation — which rebuilds
    adjacency only — carries them through unchanged: the merge never
    reads or writes attribute columns, and a snapshot taken mid-window
    needs no attribute copy."""
    n: int                # high-water mark at snapshot time
    rows: np.ndarray      # [n, R] int32 adjacency at snapshot time
    alive: np.ndarray     # [n] bool alive bitset at snapshot time


def snapshot_tiered(backend, chunk=4096) -> TieredSnapshot:
    """Freeze the topology + alive bitset for a background consolidation.
    Rows stream through ``peek`` in bounded chunks (no window thrash).
    Caller serializes with the update stream (one brief lock hold)."""
    n = backend.n
    rows = np.empty((n, backend.degree), np.int32)
    for s in range(0, n, chunk):
        ids = np.arange(s, min(s + chunk, n))
        rows[ids] = backend.store.peek_rows(ids)
    return TieredSnapshot(int(n), rows, backend.alive[:n].copy())


def merge_consolidated_tiered(backend, snap: TieredSnapshot, new_rows,
                              rev_logs, chunk=4096) -> None:
    """Publish a background tiered consolidation (the disk-tier twin of
    ``merge_consolidated``): window deletions stay authoritative (dead
    rows cleared, edges to window-dead vertices scrubbed), window
    reverse-edge triplets are re-applied onto the consolidated rows with
    ``insert_batch``'s free-slot / replace-worst / last-writer-wins
    semantics, vertices inserted after the snapshot (id >= snap.n) keep
    their active-store rows verbatim, and e_in is rebuilt over the merged
    graph. ``rev_logs`` is the *sequence* of per-insert-batch RevLogs
    logged during the window, replayed batch by batch (slots are
    recomputed between batches, exactly as the live path applied them —
    one concatenated one-shot replay would collapse every window edge of
    a target onto a single slot and drop acknowledged edges). Caller
    serializes with the update stream.

    Durability (core/wal.py): the merge's full edit set — every (ids,
    rows) group it will write — is collected FIRST, logged as ONE
    CONSOLIDATE record when a WAL is attached, then applied by
    ``apply_merge_edits`` (the same function WAL replay calls). The merge
    is thereby atomic across a crash: a committed record means recovery
    completes it, a torn record means it never happened — either way the
    store is a state the uninterrupted run passed through."""
    store = backend.store
    R = backend.degree
    alive = backend.alive
    rows = np.asarray(new_rows, np.int32).copy()

    # reverse-edge integration: both endpoints must still be alive
    for log in rev_logs:
        v = np.asarray(log.v, np.int64)
        v_new = np.asarray(log.v_new, np.int64)
        d = np.asarray(log.d, np.float32)
        ok = (v >= 0) & (v < snap.n) & alive[np.clip(v, 0, None)] \
            & alive[np.clip(v_new, 0, None)]
        v, v_new, d = v[ok], v_new[ok], d[ok]
        if not v.size:
            continue
        ut, inv = np.unique(v, return_inverse=True)
        trow = rows[ut]
        tvec, _ = store.peek(ut)
        rvec, _ = store.peek(np.clip(trow, 0, None).reshape(-1))
        rows[ut] = reverse_edge_rows_host(
            trow, tvec, rvec.reshape(ut.size, R, -1), inv, v_new, d)

    # window deletions are authoritative on the consolidated rows
    rows[(rows >= 0) & ~alive[np.clip(rows, 0, None)]] = -1
    rows[~alive[:snap.n]] = -1

    # collect the edit set WITHOUT touching the store, so it can be WAL-
    # logged as one atomic record before any byte moves. Edits name ONLY
    # rows the rebuild/replay/scrub actually changed vs the frozen
    # topology; untouched rows keep their live store contents
    # (live-applied window reverse edges on a consolidation-untouched row
    # are bitwise-identical to the replay's result, so skipping them is
    # exact) — the caller holds the update lock, so the critical section
    # must be proportional to the consolidation's edit set, not the
    # dataset.
    changed = np.where((rows != snap.rows).any(axis=1))[0]
    edits = [(changed, rows[changed])]
    is_changed = np.zeros((snap.n,), bool)
    is_changed[changed] = True

    # live rows untouched by the rebuild may still carry reverse edges
    # (applied during the window) to vertices inserted and then deleted
    # within the same window — the replay filter drops those edges from
    # `rows`, leaving rows[u] == snap.rows[u] and u outside `changed`.
    # Every such row is named as a target by the logs, so the scrub set
    # stays bounded by window activity. The scrub is computed against the
    # state the changed-group writes WILL leave (overlay), preserving the
    # sequential read-after-write semantics of the pre-WAL merge.
    stale = np.unique(np.concatenate(
        [np.asarray(log.v, np.int64)[
            ~alive[np.clip(np.asarray(log.v_new, np.int64), 0, None)]]
         for log in rev_logs] or [np.zeros((0,), np.int64)]))
    stale = stale[(stale >= 0) & (stale < snap.n)]
    s_ids, s_rows = [], []
    for s in range(0, stale.size, chunk):
        ids = stale[s:s + chunk]
        r = store.peek_rows(ids)
        m = is_changed[ids]
        if m.any():
            r[m] = rows[ids[m]]
        dead = (r >= 0) & ~alive[np.clip(r, 0, None)]
        if dead.any():
            r[dead] = -1
            sel = dead.any(axis=1)
            s_ids.append(ids[sel])
            s_rows.append(r[sel])
    if s_ids:
        edits.append((np.concatenate(s_ids), np.concatenate(s_rows)))

    # incremental subgraph appending: rows past the snapshot stay
    # verbatim except that window deletions are authoritative there too
    # (a window insert may have linked to a vertex deleted later in the
    # window)
    n = backend.n
    a_ids, a_rows = [], []
    for s in range(snap.n, n, chunk):
        ids = np.arange(s, min(s + chunk, n))
        r = store.peek_rows(ids)
        dead = (r >= 0) & ~alive[np.clip(r, 0, None)]
        if dead.any():
            r[dead] = -1
            sel = dead.any(axis=1)
            a_ids.append(ids[sel])
            a_rows.append(r[sel])
    if a_ids:
        edits.append((np.concatenate(a_ids), np.concatenate(a_rows)))

    if backend.wal is not None:
        from repro.core import wal as walmod
        backend.wal.append(walmod.REC_CONSOLIDATE, {
            "ids": [np.asarray(g[0], np.int64) for g in edits],
            "rows": [np.asarray(g[1], np.int32) for g in edits]})
    apply_merge_edits(backend, edits, chunk=chunk)


def apply_merge_edits(backend, edits, chunk=4096) -> None:
    """Mutation half of ``merge_consolidated_tiered``, shared verbatim
    with WAL replay: write each (ids, rows) edit group in order, with
    incremental e_in accounting against the rows being replaced (entries
    a group leaves in place cancel exactly, so full-row subtract/add
    equals the per-entry deltas of the pre-WAL merge). e_in is published
    in one assignment at the end, like every directory update."""
    from repro.core.wal import crash_point
    store = backend.store
    e_in = backend.e_in.copy()
    first = True
    for gids, grows in edits:
        gids = np.asarray(gids, np.int64)
        grows = np.asarray(grows, np.int32)
        for s in range(0, gids.size, chunk):
            ids = gids[s:s + chunk]
            new = grows[s:s + chunk]
            old = store.peek_rows(ids)
            np.subtract.at(e_in, old[old >= 0], 1)
            np.add.at(e_in, new[new >= 0], 1)
            store.write(ids, None, new)
            backend.version[ids] += 1
            if first:               # merge partially published, rest pending
                crash_point("mid_consolidation_merge")
                first = False
    backend.e_in = e_in
