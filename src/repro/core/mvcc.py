"""Multi-version mechanism (paper §5.3).

A consolidation (or large repair) runs on an immutable snapshot G_t0 while
foreground inserts/deletes/searches continue on the active graph G_t1.
At completion the background result G'_t0 is merged:

* **Incremental subgraph appending** — vertices inserted after the snapshot
  (id >= snapshot_n) keep their active-graph rows verbatim.
* **Reverse-edge integration** — reverse-edge triplets (v, v_new, d) logged
  during the window are re-applied onto the consolidated rows of old
  vertices.
* deletions that happened during the window stay authoritative (the alive
  bitset is taken from the active graph).

A bounded-version policy (engine.py) defers new snapshots once the limit is
reached.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.types import GraphState, IndexState
from repro.core.build import compute_e_in
from repro.core.update import RevLog, _reverse_edge_scatter


@jax.jit
def merge_consolidated(consolidated: IndexState, active: IndexState,
                       snapshot_n, rev_log: RevLog) -> IndexState:
    """Merge background-consolidated snapshot into the active state."""
    gc, ga = consolidated.graph, active.graph
    N = ga.capacity
    is_new = jnp.arange(N, dtype=jnp.int32) >= snapshot_n

    # old rows from the consolidated graph, new rows appended from active
    nbrs = jnp.where(is_new[:, None], ga.nbrs, gc.nbrs)
    graph = ga._replace(nbrs=nbrs)

    # re-apply window reverse edges onto consolidated old rows
    apply_mask = (rev_log.v >= 0) & (rev_log.v < snapshot_n) \
        & graph.alive[jnp.clip(rev_log.v, 0)] \
        & graph.alive[jnp.clip(rev_log.v_new, 0)]
    targets = jnp.where(apply_mask, rev_log.v, -1)
    nbrs = _reverse_edge_scatter(graph, targets, rev_log.v_new, rev_log.d)
    graph = graph._replace(nbrs=nbrs,
                           e_in=compute_e_in(nbrs, N),
                           version=jnp.maximum(ga.version, gc.version) + 1)
    return IndexState(graph, active.cache, active.stats)


def empty_rev_log() -> RevLog:
    z = jnp.zeros((0,), jnp.int32)
    return RevLog(z, z, jnp.zeros((0,), jnp.float32))


def concat_rev_logs(logs) -> RevLog:
    logs = [l for l in logs if l.v.shape[0]]
    if not logs:
        return empty_rev_log()
    return RevLog(jnp.concatenate([l.v for l in logs]),
                  jnp.concatenate([l.v_new for l in logs]),
                  jnp.concatenate([l.d for l in logs]))
