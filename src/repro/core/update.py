"""Index updates (paper §5): batched insertion with rank-based candidate
reordering + reverse edges; three-stage deletion (logical bitset →
localized topology-aware repair → global consolidation).

All functions are functional: state in, state out. ``insert_batch`` also
returns the reverse-edge triplet log (v, v_new, d) consumed by the MVCC
merge protocol (paper §5.3) when a consolidation snapshot is in flight.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.build import compute_e_in, rank_based_reorder
from repro.core.search import _frontier_search, dedup_mask
from repro.core.types import GraphState, IndexState, SearchParams

INF = jnp.float32(jnp.inf)


class RevLog(NamedTuple):
    """Reverse-edge triplets (paper §5.3 'Reverse Edge Integration')."""
    v: jax.Array       # [E] existing vertex receiving the edge
    v_new: jax.Array   # [E] newly inserted vertex
    d: jax.Array       # [E] precomputed distance


def _reverse_edge_scatter(graph: GraphState, targets, new_ids, dists):
    """Vectorized reverse-edge insertion. For each edge (targets[e] ->
    new_ids[e]): use a free slot if any, else replace the current worst
    (farthest) neighbor if the new edge is closer. Write conflicts resolve
    last-writer-wins (the paper uses best-effort atomics + thread-local
    buffers; KNNG is approximate by construction)."""
    R = graph.degree
    t = jnp.clip(targets, 0)
    rows = graph.nbrs[t]                                   # [E, R]
    tvec = graph.vectors[t]
    nb_d = jnp.sum((graph.vectors[jnp.clip(rows, 0)]
                    - tvec[:, None, :]) ** 2, axis=-1)
    nb_d = jnp.where(rows >= 0, nb_d, -INF)                # free slots win
    worst = jnp.argmax(jnp.where(rows < 0, INF, nb_d), axis=1)
    has_free = (rows < 0).any(axis=1)
    free_idx = jnp.argmax(rows < 0, axis=1)
    slot = jnp.where(has_free, free_idx, worst)
    improves = has_free | (dists < jnp.max(nb_d, axis=1))
    ok = (targets >= 0) & improves
    row_idx = jnp.where(ok, t, graph.capacity)             # no-op row
    nbrs_pad = jnp.concatenate(
        [graph.nbrs, jnp.full((1, R), -1, jnp.int32)], axis=0)
    nbrs_pad = nbrs_pad.at[row_idx, slot].set(
        jnp.where(ok, new_ids, -1))
    return nbrs_pad[:graph.capacity]


@partial(jax.jit, static_argnames=("sp",))
def insert_batch(state: IndexState, new_vecs, key, sp: SearchParams):
    """Insert a batch. Returns (state, new_ids, RevLog)."""
    graph, cache, stats = state.graph, state.cache, state.stats
    Bi, D = new_vecs.shape
    new_vecs = new_vecs.astype(jnp.float32)
    ids = graph.n + jnp.arange(Bi, dtype=jnp.int32)

    # phase 1 (paper §5.1): GPU-side candidate search on the current graph
    # (through the shared hop-batched frontier executor)
    n = jnp.maximum(graph.n, 1)
    entries = jax.random.randint(key, (Bi, sp.pool), 0, n, dtype=jnp.int32)
    res = _frontier_search(graph, cache, new_vecs, entries,
                           sp._replace(k=sp.pool))
    cand_ids, cand_d = res.ids, res.dists                  # [Bi, L] sorted

    # phase 2: heuristic (rank-based) reordering then edge establishment
    sel = rank_based_reorder(cand_ids, cand_d, graph.nbrs, graph.degree)

    vectors = graph.vectors.at[ids].set(new_vecs)
    nbrs = graph.nbrs.at[ids].set(sel)
    alive = graph.alive.at[ids].set(True)
    version = graph.version.at[ids].set(1)
    graph = graph._replace(vectors=vectors, nbrs=nbrs, alive=alive,
                           version=version, n=graph.n + Bi)

    # reverse edges (flattened over the batch)
    flat_t = sel.reshape(-1)
    flat_new = jnp.repeat(ids, graph.degree)
    d_rev = jnp.sum((graph.vectors[jnp.clip(flat_t, 0)]
                     - graph.vectors[flat_new]) ** 2, axis=-1)
    d_rev = jnp.where(flat_t >= 0, d_rev, INF)
    nbrs = _reverse_edge_scatter(graph, flat_t, flat_new, d_rev)
    version = graph.version.at[jnp.clip(flat_t, 0)].add(
        (flat_t >= 0).astype(jnp.int32))
    graph = graph._replace(nbrs=nbrs, version=version)
    graph = graph._replace(e_in=compute_e_in(graph.nbrs, graph.capacity))
    return (IndexState(graph, cache, stats), ids,
            RevLog(flat_t, flat_new, d_rev))


@jax.jit
def delete_batch(state: IndexState, ids):
    """Stage 1 (paper §5.2.1): logical deletion. The bitset is shared by all
    tiers (immediate cross-tier sync); searches/insertions skip marked rows
    transparently."""
    graph, cache, stats = state.graph, state.cache, state.stats
    cid = jnp.clip(ids, 0)
    ok = (ids >= 0) & graph.alive[cid]
    alive = graph.alive.at[cid].set(jnp.where(ok, False, graph.alive[cid]))
    version = graph.version.at[cid].add(ok.astype(jnp.int32))
    return IndexState(graph._replace(alive=alive, version=version),
                      cache, stats)


def deleted_fraction(graph: GraphState) -> jax.Array:
    within = jnp.arange(graph.capacity) < graph.n
    dead = within & ~graph.alive
    return dead.sum() / jnp.maximum(graph.n, 1)


def affected_fraction(graph: GraphState):
    """Per-vertex fraction of deleted out-neighbors."""
    nb = graph.nbrs
    valid = nb >= 0
    dead = valid & ~graph.alive[jnp.clip(nb, 0)]
    return dead.sum(1) / jnp.maximum(valid.sum(1), 1)


@partial(jax.jit, static_argnames=("max_repair", "c"))
def repair_affected(state: IndexState, *, max_repair=256, c=2,
                    threshold=0.5):
    """Stage 2 (paper §5.2.2): localized topology-aware repair. For the most
    affected alive vertices (deleted-neighbor fraction > 50%), each deleted
    neighbor p contributes at most ``c`` of its own alive out-neighbors
    (nearest to v) as replacement edges — O(c) per deletion instead of the
    full consolidation O(|N_out(p)|)."""
    graph, cache, stats = state.graph, state.cache, state.stats
    frac = affected_fraction(graph)
    score = jnp.where(graph.alive & (frac > threshold), frac, -1.0)
    _, vsel = jax.lax.top_k(score, max_repair)
    do = score[vsel] > 0

    R = graph.degree

    def repair_one(v, active):
        row = graph.nbrs[v]
        valid = row >= 0
        dead = valid & ~graph.alive[jnp.clip(row, 0)]
        hop2 = graph.nbrs[jnp.clip(row, 0)]                # [R, R]
        vvec = graph.vectors[v]
        d2 = jnp.sum((graph.vectors[jnp.clip(hop2, 0)]
                      - vvec[None, None, :]) ** 2, axis=-1)
        ok2 = (hop2 >= 0) & graph.alive[jnp.clip(hop2, 0)] & (hop2 != v) \
            & dead[:, None]                                # only via deleted p
        # not already a live neighbor
        dup = (hop2[:, :, None] == jnp.where(dead, -1, row)[None, None, :]
               ).any(-1)
        d2 = jnp.where(ok2 & ~dup, d2, INF)
        # at most c per deleted neighbor
        dtop, itop = jax.lax.top_k(-d2, c)                 # [R, c]
        cand = jnp.take_along_axis(hop2, itop, axis=1).reshape(-1)
        cd = (-dtop).reshape(-1)
        # dedup candidates
        dupc = jnp.triu(cand[:, None] == cand[None, :], k=1).any(0)
        cd = jnp.where(jnp.isfinite(cd) & ~dupc, cd, INF)
        order = jnp.argsort(cd)
        cand, cd = cand[order], cd[order]
        n_dead = dead.sum()
        # fill dead slots with best candidates
        slot_rank = jnp.cumsum(dead) - 1                   # rank per dead slot
        fill = jnp.where(jnp.isfinite(cd[jnp.clip(slot_rank, 0, cand.shape[0] - 1)]),
                         cand[jnp.clip(slot_rank, 0, cand.shape[0] - 1)], -1)
        new_row = jnp.where(dead, fill, row)
        return jnp.where(active, new_row, row)

    new_rows = jax.vmap(repair_one)(jnp.clip(vsel, 0), do)
    nbrs = graph.nbrs.at[jnp.clip(vsel, 0)].set(new_rows)
    version = graph.version.at[jnp.clip(vsel, 0)].add(do.astype(jnp.int32))
    graph = graph._replace(nbrs=nbrs, version=version)
    graph = graph._replace(e_in=compute_e_in(graph.nbrs, graph.capacity))
    return IndexState(graph, cache, stats), do.sum()


# ---------------------------------------------------------------------------
# Tiered (disk-backed) update path — numpy twins of the jitted transforms,
# streaming through the TieredStore so the working set stays bounded.
# ---------------------------------------------------------------------------

def rank_based_reorder_host(cand_ids, cand_d, cand_rows, degree):
    """Numpy twin of ``build.rank_based_reorder`` for the tiered path:
    the candidates' neighbor rows arrive pre-fetched (``cand_rows``
    [B, C, R]) instead of being gathered from a device-resident table."""
    B, C = cand_ids.shape
    eq = (cand_rows[:, :, :, None] == cand_ids[:, None, None, :]).any(axis=2)
    tri = np.tril(np.ones((C, C), bool), k=-1).T         # j < i mask at [j, i]
    detours = (eq & tri[None]).sum(axis=1)               # [B, C_i]
    invalid = cand_ids < 0
    detours = np.where(invalid, C + 1, detours)
    rank_d = np.argsort(np.argsort(cand_d, axis=1, kind="stable"),
                        axis=1, kind="stable")
    order = np.argsort(detours.astype(np.float64) * 1e6 + rank_d,
                       axis=1, kind="stable")
    take = min(degree, C)
    sel_ids = np.take_along_axis(cand_ids, order[:, :take], axis=1)
    sel_det = np.take_along_axis(detours, order[:, :take], axis=1)
    sel = np.where(sel_det > C, -1, sel_ids).astype(np.int32)
    if take < degree:
        sel = np.concatenate(
            [sel, np.full((B, degree - take), -1, np.int32)], axis=1)
    return sel


def reverse_edge_rows_host(trow, tvec, nbr_vecs, inv, new_ids, d_edge):
    """One-shot reverse-edge application over fetched target rows — the
    numpy twin of ``_reverse_edge_scatter`` shared by ``insert_tiered``
    and the tiered MVCC merge. For edge e (target ``inv[e]`` -> vertex
    ``new_ids[e]`` at distance ``d_edge[e]``): use a free slot if any,
    else replace the current worst neighbor if the new edge is closer;
    write conflicts resolve last-writer-wins. Returns the updated rows
    [U, R] (``trow`` is not mutated)."""
    nb_d = ((nbr_vecs - tvec[:, None, :]) ** 2).sum(-1)          # [U, R]
    occ = trow >= 0
    worst = np.argmax(np.where(occ, nb_d, -np.inf), axis=1)
    has_free = (~occ).any(axis=1)
    free_idx = np.argmax(~occ, axis=1)
    slot = np.where(has_free, free_idx, worst)
    max_d = np.where(occ, nb_d, -np.inf).max(axis=1)
    improves = has_free[inv] | (d_edge < max_d[inv])
    out = trow.copy()
    out[inv[improves], slot[inv][improves]] = \
        np.asarray(new_ids)[improves].astype(np.int32)
    return out


def insert_tiered(backend, cache_mirror, new_vecs, sp: SearchParams, seed,
                  attributes=None):
    """Batched insertion against the disk-backed capacity tier (paper §5.1
    over the three-tier hierarchy): candidate search cascades through the
    store, new rows are written through the host window, and reverse edges
    are applied to the fetched target rows with the same free-slot /
    replace-worst / last-writer-wins semantics as ``insert_batch``.
    Returns ``(new_ids, RevLog)`` — the reverse-edge triplet log (numpy
    arrays) is consumed by the tiered MVCC merge when a consolidation
    snapshot is in flight. Caller serializes (engine update stream).

    Durability split (core/wal.py): the op's FULL effect — selected rows
    and reverse-edge triplets — is computed here against the unmutated
    store (every reverse-edge target pre-exists the batch, so its vector
    is already durable), logged to the WAL when one is attached, and only
    then applied by ``apply_insert_tiered`` — the same function crash
    recovery replays, so a recovered index is bit-identical to an
    uninterrupted run by construction.

    ``attributes`` (optional) is the batch's filter-attribute payload in
    any form ``filters.AttributeSchema.coerce`` accepts (dict of
    columns, (tags, nums) pair, or None for schema defaults); requires
    an attached ``backend.attrs`` store.
    """
    from repro.core.search import search_tiered
    store = backend.store
    new_vecs = np.asarray(new_vecs, np.float32)
    Bi = new_vecs.shape[0]
    R = backend.degree
    n0 = backend.n
    if n0 + Bi > backend.capacity:
        raise ValueError(f"disk tier full: {n0}+{Bi} > {backend.capacity}")
    ids = (n0 + np.arange(Bi)).astype(np.int64)
    # one O(capacity) F_λ pass shared by the candidate search, the row
    # fetches and the reverse-edge pass below
    f_lam = cache_mirror.scores(backend.e_in)

    # phase 1: candidate search on the current graph
    res = search_tiered(backend, cache_mirror, new_vecs, seed,
                        sp._replace(k=sp.pool), f_lam=f_lam)
    cand_ids, cand_d = res.ids.astype(np.int64), res.dists

    # phase 2: rank-based reorder over the candidates' (fetched) rows
    uc = np.unique(np.clip(cand_ids, 0, None))
    _, urows = store.fetch(uc, f_lam)
    cand_rows = urows[np.searchsorted(uc, np.clip(cand_ids, 0, None))]
    cand_rows[cand_ids < 0] = -1
    sel = rank_based_reorder_host(cand_ids, cand_d, cand_rows, R)

    # reverse-edge triplets, pre-mutation (targets all pre-exist: their
    # vectors are immutable and the distances are computable now)
    flat_t = sel.reshape(-1).astype(np.int64)
    flat_new = np.repeat(ids, R)
    ok = flat_t >= 0
    flat_t, flat_new = flat_t[ok], flat_new[ok]
    d_edge = np.zeros((0,), np.float32)
    if flat_t.size:
        ut, inv = np.unique(flat_t, return_inverse=True)
        tvec, _ = store.fetch(ut, f_lam)
        d_edge = ((tvec[inv] - new_vecs[(flat_new - n0)]) ** 2).sum(-1)
    rev = RevLog(flat_t.astype(np.int64), flat_new.astype(np.int64),
                 np.asarray(d_edge, np.float32))

    # attribute columns: coerce through the index schema so the WAL
    # record and the live apply share one validated column form
    tags = nums = None
    if backend.attrs is not None:
        tags, nums = backend.attrs.schema.coerce(attributes, Bi)
    elif attributes is not None:
        raise ValueError("attributes passed but no attribute store is "
                         "attached (set EngineConfig.attributes)")

    if backend.wal is not None:
        from repro.core import wal as walmod
        payload = {"ids": ids, "vecs": new_vecs, "sel": sel,
                   "rev_v": rev.v, "rev_vn": rev.v_new, "rev_d": rev.d}
        if tags is not None:
            payload["tags"], payload["nums"] = tags, nums
        backend.wal.append(walmod.REC_INSERT, payload)
    apply_insert_tiered(backend, ids, new_vecs, sel, rev, f_lam=f_lam,
                        tags=tags, nums=nums)
    return ids, rev


def apply_insert_tiered(backend, ids, new_vecs, sel, rev: RevLog,
                        f_lam=None, tags=None, nums=None) -> None:
    """Mutation half of ``insert_tiered``, shared verbatim with WAL
    replay (``wal.recover``): establish the new vertices, encode their PQ
    codes against the frozen codebook, then apply the logged reverse
    edges onto freshly fetched target rows. Replaying this over the
    snapshot's state walks the store through the exact same write
    sequence as the live run."""
    from repro.core.wal import crash_point
    store = backend.store
    ids = np.asarray(ids, np.int64)
    new_vecs = np.asarray(new_vecs, np.float32)
    if not len(ids):
        return
    n0 = int(ids[0])
    if n0 != backend.n:
        raise ValueError(f"insert replay out of order: record starts at id "
                         f"{n0}, store high-water mark is {backend.n}")
    R = backend.degree

    # establish new vertices (write-through keeps the overlay coherent);
    # the PQ code lane encodes incrementally against its frozen codebook
    # so the device-resident ADC scan covers the new ids from the next
    # search's epoch sync onward
    store.write(ids, new_vecs, sel)
    crash_point("mid_memmap_write")   # new rows written, reverse edges not
    if backend.pq is not None:
        backend.pq.encode_write(ids, new_vecs)
    if backend.attrs is not None and tags is not None:
        backend.attrs.write(ids, tags, nums)
    backend.alive[ids] = True
    backend.version[ids] = 1
    sel = np.asarray(sel, np.int32)
    np.add.at(backend.e_in, sel[sel >= 0], 1)
    backend.n = int(n0 + len(ids))

    # reverse edges (flattened over the batch, original-rows semantics)
    v = np.asarray(rev.v, np.int64)
    if v.size:
        v_new = np.asarray(rev.v_new, np.int64)
        d_edge = np.asarray(rev.d, np.float32)
        ut, inv = np.unique(v, return_inverse=True)
        tvec, trow = store.fetch(ut, f_lam)
        rvec, _ = store.peek(np.clip(trow, 0, None).reshape(-1))
        new_rows = reverse_edge_rows_host(
            trow, tvec, rvec.reshape(ut.size, R, -1), inv, v_new, d_edge)
        np.add.at(backend.e_in, trow[trow >= 0], -1)
        np.add.at(backend.e_in, new_rows[new_rows >= 0], 1)
        store.write(ut, None, new_rows)
        backend.version[ut] += 1


def delete_tiered(backend, ids) -> np.ndarray:
    """Logical deletion on the tiered backend (stage 1, paper §5.2.1):
    bounds-filter, drop already-dead ids, WAL the surviving set, then
    flip the bitset. Returns the ids actually deleted. Caller serializes
    (engine update stream)."""
    ids_np = np.asarray(ids, np.int64)
    ids_np = ids_np[(ids_np >= 0) & (ids_np < backend.n)]
    ids_np = ids_np[backend.alive[ids_np]]
    if backend.wal is not None and ids_np.size:
        from repro.core import wal as walmod
        backend.wal.append(walmod.REC_DELETE, {"ids": ids_np})
    apply_delete_tiered(backend, ids_np)
    return ids_np


def apply_delete_tiered(backend, ids_np) -> None:
    """Mutation half of ``delete_tiered`` (records are pre-filtered)."""
    ids_np = np.asarray(ids_np, np.int64)
    backend.alive[ids_np] = False
    backend.version[ids_np] += 1


def consolidate_tiered(backend, chunk=256, *, snapshot=None):
    """Stage 3 (paper §5.2.2) for the disk tier, MVCC form (paper §5.3):
    global consolidation computed against a *frozen* topology snapshot
    while inserts/deletes/searches continue on the active store. Per
    snapshot-alive vertex, the neighbor list is rebuilt from {alive
    out-neighbors} ∪ {alive out-neighbors of deleted out-neighbors},
    pruned to degree by distance; dead rows are cleared. Adjacency comes
    from ``snapshot.rows`` (never the live store); vectors are immutable
    per id, so they stream through ``peek`` (bounded chunks, no window
    thrash). Returns the rebuilt rows [snapshot.n, R] WITHOUT publishing
    them — callers publish via ``mvcc.merge_consolidated_tiered``, which
    re-applies the window's reverse-edge log and makes window deletions
    authoritative. When ``snapshot`` is None a snapshot is taken and the
    result merged in place (serial mode: no concurrent update stream).
    """
    from repro.core import mvcc
    serial = snapshot is None
    if serial:
        snapshot = mvcc.snapshot_tiered(backend)
    store = backend.store
    R = backend.degree
    snap_rows, snap_alive = snapshot.rows, snapshot.alive
    snap_n = snapshot.n
    new_rows = snap_rows.copy()
    for s in range(0, snap_n, chunk):
        ids = np.arange(s, min(s + chunk, snap_n))
        C = ids.size
        rows = snap_rows[ids]
        valid = rows >= 0
        dead = valid & ~snap_alive[np.clip(rows, 0, None)]
        if not dead.any() and bool(snap_alive[ids].all()):
            continue
        svec, _ = store.peek(ids)
        hop2 = np.full((C, R, R), -1, np.int32)
        if dead.any():
            hop2[dead] = snap_rows[rows[dead]]       # frozen topology
        cand = np.concatenate(
            [np.where(dead, -1, rows), hop2.reshape(C, R * R)], axis=1)
        okc = (cand >= 0) & snap_alive[np.clip(cand, 0, None)] \
            & (cand != ids[:, None])
        cu = np.unique(np.clip(cand, 0, None))
        cvec, _ = store.peek(cu)
        clut = np.zeros((int(cu.max()) + 2,), np.int64)
        clut[cu] = np.arange(cu.size)
        d = ((cvec[clut[np.clip(cand, 0, None)]]
              - svec[:, None, :]) ** 2).sum(-1)
        d = np.where(okc & ~dedup_mask(cand), d, np.inf)
        top = np.argpartition(d, min(R, d.shape[1]) - 1, axis=1)[:, :R]
        dtop = np.take_along_axis(d, top, axis=1)
        o = np.argsort(dtop, axis=1, kind="stable")
        top = np.take_along_axis(top, o, axis=1)
        dtop = np.take_along_axis(dtop, o, axis=1)
        out = np.where(np.isfinite(dtop),
                       np.take_along_axis(cand, top, axis=1),
                       -1).astype(np.int32)
        out[~snap_alive[ids]] = -1
        new_rows[ids] = out
    if serial:
        mvcc.merge_consolidated_tiered(backend, snapshot, new_rows, [])
    return new_rows


@partial(jax.jit, static_argnames=("chunk",))
def consolidate(state: IndexState, *, chunk=512):
    """Stage 3 (paper §5.2.2): global consolidation. For every alive vertex,
    rebuild its neighbor list from {alive out-neighbors} ∪ {alive
    out-neighbors of its deleted out-neighbors}, pruned to degree by
    distance. Dead rows are cleared. Runs on a snapshot in the engine
    (MVCC) so foreground ops never block on it."""
    graph, cache, stats = state.graph, state.cache, state.stats
    R = graph.degree
    N = graph.capacity

    def rebuild(v):
        row = graph.nbrs[v]
        valid = row >= 0
        dead = valid & ~graph.alive[jnp.clip(row, 0)]
        hop2 = graph.nbrs[jnp.clip(row, 0)]                # [R, R]
        hop2 = jnp.where(dead[:, None], hop2, -1)          # only via deleted
        cand = jnp.concatenate([jnp.where(dead, -1, row), hop2.reshape(-1)])
        okc = (cand >= 0) & graph.alive[jnp.clip(cand, 0)] & (cand != v)
        vvec = graph.vectors[v]
        d = jnp.sum((graph.vectors[jnp.clip(cand, 0)] - vvec) ** 2, axis=-1)
        dup = jnp.triu(cand[:, None] == cand[None, :], k=1).any(0)
        d = jnp.where(okc & ~dup, d, INF)
        nd, it = jax.lax.top_k(-d, R)
        new_row = jnp.where(jnp.isfinite(-nd), cand[it], -1)
        return jnp.where(graph.alive[v], new_row, jnp.full((R,), -1, jnp.int32))

    ids = jnp.arange(N, dtype=jnp.int32).reshape(-1, chunk)
    nbrs = jax.lax.map(jax.vmap(rebuild), ids).reshape(N, R)
    graph = graph._replace(nbrs=nbrs,
                           version=graph.version + 1)
    graph = graph._replace(e_in=compute_e_in(graph.nbrs, N))
    return IndexState(graph, cache, stats)
