"""Index construction (paper §4.2).

GPU-parallel strategy à la CAGRA: the dataset is partitioned to fit the
bandwidth tier, a KNN subgraph is built per partition with brute-force
distance GEMMs (MXU-friendly), and partitions are merged on the capacity
tier within a bounded memory window — cross-partition candidate edges come
from sampled inter-partition distance blocks, then rank-based reordering
(paper §5.1) prunes to the fixed out-degree and reverse edges are added.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import (GraphState, IndexState, init_cache_state,
                              init_graph_state, init_stats)


def pairwise_l2(a, b):
    """Squared L2 distances [n, m] via the GEMM form ||a||² - 2ab + ||b||²."""
    a2 = jnp.sum(a * a, axis=1, keepdims=True)
    b2 = jnp.sum(b * b, axis=1, keepdims=True)
    return a2 - 2.0 * (a @ b.T) + b2.T


def _exact_knn(vectors, k, chunk=2048):
    """Top-k neighbor ids for every row (excluding self). Chunked GEMMs.
    If the dataset has fewer than k+1 rows, pads with -1."""
    n = vectors.shape[0]
    k_eff = max(1, min(k, n - 1))
    ids = []
    for s in range(0, n, chunk):
        d = pairwise_l2(vectors[s:s + chunk], vectors)
        rows = jnp.arange(s, min(s + chunk, n)) - s
        d = d.at[rows, jnp.arange(s, min(s + chunk, n))].set(jnp.inf)
        _, idx = jax.lax.top_k(-d, k_eff)
        ids.append(idx)
    out = jnp.concatenate(ids, axis=0)
    if k_eff < k:
        out = jnp.concatenate(
            [out, jnp.full((n, k - k_eff), -1, out.dtype)], axis=1)
    return out


def rank_based_reorder(cand_ids, cand_dists, nbrs, degree):
    """Paper §5.1: sort candidates by detourable-path count (ascending).

    For candidate i, count occurrences of cand[i] in the neighbor lists of
    earlier candidates j < i; fewer detours = more valuable direct edge.
    cand_ids/[B, C] sorted by distance; nbrs [N, R]. Returns [B, degree].
    """
    B, C = cand_ids.shape

    def per_query(cids, cds):
        cn = nbrs[jnp.clip(cids, 0)]                       # [C, R]
        # detour[i] = #{j < i : cids[i] in nbrs[cids[j]]}
        eq = jnp.any(cn[:, :, None] == cids[None, None, :], axis=1)  # [C_j, C_i]
        tri = jnp.tril(jnp.ones((C, C), bool), k=-1).T      # j < i mask at [j, i]
        detours = jnp.sum(eq & tri, axis=0)                 # [C_i]
        invalid = cids < 0
        detours = jnp.where(invalid, C + 1, detours)
        # stable sort by (detours, distance)
        order = jnp.argsort(detours.astype(jnp.float32) * 1e6
                            + jnp.argsort(jnp.argsort(cds)).astype(jnp.float32))
        take = min(degree, C)
        sel = jnp.where(detours[order[:take]] > C, -1, cids[order[:take]])
        if take < degree:   # fewer candidates than out-degree: pad
            sel = jnp.concatenate(
                [sel, jnp.full((degree - take,), -1, jnp.int32)])
        return sel

    return jax.vmap(per_query)(cand_ids, cand_dists)


def _add_reverse_edges(nbrs_np: np.ndarray, n: int, rng: np.random.Generator):
    """Host-side exact reverse-edge pass (build time): for each edge u->v add
    v->u if v has a free slot, else replace a random slot with prob 1/2."""
    R = nbrs_np.shape[1]
    for u in range(n):
        for v in nbrs_np[u]:
            if v < 0:
                continue
            row = nbrs_np[v]
            if u in row:
                continue
            free = np.where(row < 0)[0]
            if free.size:
                row[free[0]] = u
            elif rng.random() < 0.5:
                row[rng.integers(R)] = u
    return nbrs_np


def compute_e_in(nbrs, n_max):
    flat = nbrs.reshape(-1)
    valid = flat >= 0
    return jnp.zeros((n_max,), jnp.int32).at[
        jnp.clip(flat, 0)].add(valid.astype(jnp.int32))


def build_graph(vectors, degree, n_max=None, *, n_partitions=1,
                cross_samples=128, seed=0, reverse_edges=True):
    """Build a fixed-out-degree KNN graph. Returns GraphState.

    n_partitions > 1 exercises the partitioned build+merge path (bounded
    memory window); 1 = single-partition exact build.
    """
    vectors = jnp.asarray(vectors, jnp.float32)
    n, dim = vectors.shape
    n_max = n_max or n
    rng = np.random.default_rng(seed)

    if n_partitions <= 1:
        knn = _exact_knn(vectors, degree)
    else:
        # per-partition subgraphs ("GPU build"), then bounded-window merge:
        # only candidate columns are materialized, never the full matrix.
        bounds = np.linspace(0, n, n_partitions + 1).astype(int)
        knn_rows = []
        for p in range(n_partitions):
            s, e = bounds[p], bounds[p + 1]
            local = _exact_knn(vectors[s:e], min(degree, e - s - 1)) + s
            # cross-partition candidates: sampled global columns
            samp = rng.choice(n, size=min(cross_samples, n), replace=False)
            d_cross = pairwise_l2(vectors[s:e], vectors[samp])
            k_cross = min(degree, len(samp))
            _, ci = jax.lax.top_k(-d_cross, k_cross)
            cross = jnp.asarray(samp)[ci]
            cand = jnp.concatenate([local, cross], axis=1)     # [rows, C]
            cv = vectors[cand]                                 # bounded window
            d = jnp.sum((cv - vectors[s:e][:, None, :]) ** 2, axis=-1)
            rows = jnp.arange(s, e)
            d = jnp.where(cand == rows[:, None], jnp.inf, d)
            # drop duplicate candidate ids (keep first occurrence)
            dup = jnp.triu(cand[:, :, None] == cand[:, None, :], k=1).any(1)
            d = jnp.where(dup, jnp.inf, d)
            cand = jnp.where(dup, -1, cand)
            order = jnp.argsort(d, axis=1)
            knn_rows.append((jnp.take_along_axis(cand, order, axis=1),
                             jnp.take_along_axis(d, order, axis=1)))
        # rank-based reorder prunes merged candidates to the fixed degree
        zero_nbrs = jnp.full((n, degree), -1, jnp.int32)
        pruned = [rank_based_reorder(c.astype(jnp.int32), dd, zero_nbrs, degree)
                  for c, dd in knn_rows]
        knn = jnp.concatenate(pruned, axis=0)

    nbrs = np.full((n_max, degree), -1, np.int32)
    nbrs[:n, :knn.shape[1]] = np.asarray(knn, np.int32)
    if reverse_edges:
        nbrs = _add_reverse_edges(nbrs, n, rng)

    g = init_graph_state(n_max, dim, degree)
    g = g._replace(
        vectors=g.vectors.at[:n].set(vectors),
        nbrs=jnp.asarray(nbrs),
        alive=g.alive.at[:n].set(True),
        n=jnp.asarray(n, jnp.int32),
    )
    return g._replace(e_in=compute_e_in(g.nbrs, n_max))


def build_tiered_backend(vectors, degree, disk_path, *, disk_capacity=None,
                         host_window=None, **kw):
    """Build the full graph, spill vectors + rows to the disk tier and
    return a ``tiers.TieredBackend`` (paper Fig. 11: the GPU-CPU-disk
    form of the index). The graph build itself runs in memory — pass
    ``n_partitions > 1`` for the bounded-window partitioned build — and
    only the per-id metadata directory (alive/e_in/version) stays host-
    resident afterwards; vectors and adjacency live behind the store.
    """
    from repro.core.tiers import DiskTier, TieredBackend, TieredStore
    vectors = np.asarray(vectors, np.float32)
    n, dim = vectors.shape
    cap = disk_capacity or n
    if cap < n:
        raise ValueError(f"disk_capacity {cap} < initial dataset {n}")
    window = host_window or max(64, cap // 4)
    g = build_graph(vectors, degree, n_max=n, **kw)
    disk = DiskTier(disk_path, cap, dim, degree)
    disk.write(np.arange(n), vectors, np.asarray(g.nbrs[:n], np.int32))
    backend = TieredBackend(TieredStore(disk, window), n)
    backend.alive[:n] = np.asarray(g.alive[:n])
    backend.e_in[:n] = np.asarray(g.e_in[:n])
    return backend


def build_index(vectors, degree=32, cache_slots=1024, n_max=None,
                theta=1.0, alpha=1.0, beta=1.0, warm=True, **kw) -> IndexState:
    """Build graph + cache tiers. Cold-start warm-up (paper §4.4) preloads
    the top-F_lambda (== top in-degree at build time) vectors."""
    g = build_graph(vectors, degree, n_max=n_max, **kw)
    c = init_cache_state(g.capacity, cache_slots, g.vectors.shape[1],
                         theta=theta, alpha=alpha, beta=beta)
    if warm:
        score = jnp.where(g.alive, jnp.log1p(g.e_in.astype(jnp.float32)), -jnp.inf)
        m = min(cache_slots, int(g.n))
        _, top = jax.lax.top_k(score, m)
        slots = jnp.arange(m, dtype=jnp.int32)
        c = c._replace(
            vectors=c.vectors.at[slots].set(g.vectors[top]),
            slot_hid=c.slot_hid.at[slots].set(top.astype(jnp.int32)),
            h2d=c.h2d.at[top].set(slots),
        )
    return IndexState(graph=g, cache=c, stats=init_stats())
