"""Pod-scale SVFusion: the paper's partitioned build/merge re-expressed over
ICI (DESIGN.md §7).

Layout on the production mesh (data axes = ("pod","data"), query axis =
"model"):

* capacity tier — vectors / graph / bitset sharded over the data axes:
  each chip owns N/P vectors and their subgraph (the paper's per-partition
  subgraphs);
* bandwidth tier — each chip's hot cache covers its own shard (mapping
  table is shard-local);
* queries — sharded over "model": each (data×model) cell searches its data
  shard for its query slice; per-shard top-k results are all-gathered over
  the data axes and merged (compute where the data lives, move only
  results — the WAVP "CPU-side compute" arm, ICI edition).

The returned step is shard_map-ped and jit-compatible; the dry-run lowers
it at Deep1B scale (1B × 96) on the 256- and 512-chip meshes.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core.search import _frontier_search
from repro.core.types import (CacheState, GraphState, SearchParams,
                              init_cache_state)


def shard_index_arrays(n_total, dim, degree, n_shards, cache_slots,
                       vec_dtype=jnp.float32):
    """Abstract shapes for the sharded index (dry-run inputs).

    vec_dtype=bf16 halves the stored footprint and (on native-bf16 TPU)
    the gather traffic of the memory-bound beam search; the CPU dry-run
    backend keeps f32 as default because its bf16 emulation materializes an
    fp32 table copy (see EXPERIMENTS.md §Perf svfusion iteration 2)."""
    import jax
    f32, i32 = jnp.float32, jnp.int32
    n_local = n_total // n_shards
    return {
        "vectors": jax.ShapeDtypeStruct((n_total, dim), vec_dtype),
        "nbrs": jax.ShapeDtypeStruct((n_total, degree), i32),
        "alive": jax.ShapeDtypeStruct((n_total,), jnp.bool_),
        "e_in": jax.ShapeDtypeStruct((n_total,), i32),
        "cache_vectors": jax.ShapeDtypeStruct(
            (n_shards * cache_slots, dim), vec_dtype),
        "slot_hid": jax.ShapeDtypeStruct((n_shards * cache_slots,), i32),
        "h2d": jax.ShapeDtypeStruct((n_total,), i32),
        "f_recent": jax.ShapeDtypeStruct((n_total,), f32),
    }


def index_shardings(data_axes=("pod", "data")):
    d = data_axes if len(data_axes) > 1 else data_axes[0]
    return {
        "vectors": P(d, None),
        "nbrs": P(d, None),
        "alive": P(d),
        "e_in": P(d),
        "cache_vectors": P(d, None),
        "slot_hid": P(d),
        "h2d": P(d),
        "f_recent": P(d),
    }


def make_distributed_search(mesh, sp: SearchParams,
                            data_axes=("pod", "data"), query_axis="model"):
    """Builds the sharded search step. Returns fn(index_arrays, queries,
    key) -> (ids [B, k], dists [B, k]) with globally valid ids.

    ``query_axis=None`` replicates queries: every chip searches its own
    partition for the whole batch (required at Deep1B scale, where the
    capacity tier must shard over every mesh axis to fit HBM)."""
    present = [a for a in data_axes if a in mesh.axis_names]
    dspec = tuple(present) if len(present) > 1 else present[0]

    qspec = P(query_axis, None) if query_axis else P(None, None)
    in_specs = (
        {"vectors": P(dspec, None), "nbrs": P(dspec, None),
         "alive": P(dspec), "e_in": P(dspec),
         "cache_vectors": P(dspec, None), "slot_hid": P(dspec),
         "h2d": P(dspec), "f_recent": P(dspec)},
        qspec,
        P(),
    )
    out_specs = (qspec, qspec)

    def step(idx, queries, key):
        n_local = idx["vectors"].shape[0]
        # shard offset -> global ids
        shard_lin = jnp.zeros((), jnp.int32)
        mul = 1
        for ax in reversed(present):
            shard_lin = shard_lin + jax.lax.axis_index(ax) * mul
            mul = mul * compat.axis_size(ax)
        offset = shard_lin.astype(jnp.int32) * n_local

        graph = GraphState(
            vectors=idx["vectors"], nbrs=idx["nbrs"], alive=idx["alive"],
            e_in=idx["e_in"],
            version=jnp.zeros((n_local,), jnp.int32),
            n=jnp.asarray(n_local, jnp.int32))
        cache = init_cache_state(n_local, idx["cache_vectors"].shape[0],
                                 idx["vectors"].shape[1])
        cache = cache._replace(vectors=idx["cache_vectors"],
                               slot_hid=idx["slot_hid"], h2d=idx["h2d"],
                               f_recent=idx["f_recent"])

        B = queries.shape[0]
        keys = jax.random.fold_in(key, shard_lin)
        entries = jax.random.randint(keys, (B, sp.pool), 0, n_local,
                                     dtype=jnp.int32)
        res = _frontier_search(graph, cache, queries, entries, sp)
        gids = jnp.where(res.ids >= 0, res.ids + offset, -1)

        # hierarchical top-k merge over the data axes (results, not rows,
        # cross the wire: k * 8B per query per shard)
        all_ids, all_d = gids, res.dists
        for ax in present:
            ai = jax.lax.all_gather(all_ids, ax, axis=0, tiled=False)
            ad = jax.lax.all_gather(all_d, ax, axis=0, tiled=False)
            ai = jnp.moveaxis(ai, 0, 1).reshape(B, -1)
            ad = jnp.moveaxis(ad, 0, 1).reshape(B, -1)
            nd, sel = jax.lax.top_k(-ad, sp.k)
            all_ids = jnp.take_along_axis(ai, sel, axis=1)
            all_d = -nd
        return all_ids, all_d

    return compat.shard_map(step, mesh=mesh, in_specs=in_specs,
                            out_specs=out_specs, check_vma=False)


def analytical_search_flops(sp: SearchParams, batch, dim, degree):
    """MODEL_FLOPS analogue for the search step (while-loop bodies are
    counted once by HLO cost analysis; this is the true per-step count):
    per query-iteration: R gathered rows × (3D flops for ||x-q||²) +
    pool merge sort ~ (L+R)·log(L+R) comparisons."""
    per_iter = degree * 3 * dim + (sp.pool + degree) * 12
    return batch * sp.max_iters * per_iter
