"""ANNS with CPU-GPU co-processing (paper Algorithm 1), TPU adaptation.

Batched greedy beam search: one vmap lane per query (the paper's
one-thread-block-per-query), neighbor expansion restructured as batched
gather + distance GEMV on the MXU. Each expansion consults the cache
mapping table; hits read the bandwidth-tier copy, misses read the capacity
tier and are logged so the post-batch WAVP pass (cache.py) can decide
promote-vs-compute-in-place with batch-amortized transfer cost (the paper
amortizes T_transfer over batches of 2048).

Returns per-query top-k plus the access/hit logs consumed by
``repro.core.cache.apply_wavp``.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import CacheState, GraphState, IndexState, SearchParams

INF = jnp.float32(jnp.inf)


class SearchResult(NamedTuple):
    ids: jax.Array        # [B, k]
    dists: jax.Array      # [B, k]
    acc_ids: jax.Array    # [B, I*R] accessed vertex ids (-1 pad)
    acc_hit: jax.Array    # [B, I*R] cache-hit flags
    iters: jax.Array      # [B] iterations used


def _gather_tiered(graph: GraphState, cache: CacheState, ids):
    """Fetch vectors for ids through the tier hierarchy: cached rows come
    from the bandwidth tier, the rest from the capacity tier."""
    slot = cache.h2d[jnp.clip(ids, 0)]
    hit = (slot >= 0) & (ids >= 0)
    dev = cache.vectors[jnp.clip(slot, 0)]
    host = graph.vectors[jnp.clip(ids, 0)]
    # NB: no astype here — converting gathered rows makes XLA hoist a full
    # fp32 copy of the table; distances accumulate in fp32 via einsum
    return jnp.where(hit[:, None], dev, host), hit


def _sqdist(x, q):
    """Squared L2 with fp32 accumulation over (possibly bf16) operands."""
    diff = x - q
    return jnp.einsum("kd,kd->k", diff, diff,
                      preferred_element_type=jnp.float32)


def _search_one(graph: GraphState, cache: CacheState, q, entry_ids,
                sp: SearchParams):
    L = sp.pool
    R = graph.degree
    I = sp.max_iters
    q = q.astype(graph.vectors.dtype)

    ev, _ = _gather_tiered(graph, cache, entry_ids)
    d0 = _sqdist(ev, q)
    d0 = jnp.where(graph.alive[entry_ids], d0, INF)
    # dedup entry ids
    dup = jnp.triu(entry_ids[:, None] == entry_ids[None, :], k=1).any(0)
    d0 = jnp.where(dup, INF, d0)
    order = jnp.argsort(d0)
    ids0, dist0 = entry_ids[order], d0[order]
    visited0 = jnp.zeros((L,), bool)

    acc_ids0 = jnp.full((I, R), -1, jnp.int32)
    acc_hit0 = jnp.zeros((I, R), bool)

    def cond(s):
        it, ids, dists, visited, *_ = s
        frontier = (~visited) & jnp.isfinite(dists)
        return (it < I) & frontier.any()

    def body(s):
        it, ids, dists, visited, acc_ids, acc_hit = s
        sel = jnp.where(visited | ~jnp.isfinite(dists), INF, dists)
        best = jnp.argmin(sel)
        curr = ids[best]
        visited = visited.at[best].set(True)

        nb = graph.nbrs[jnp.clip(curr, 0)]
        valid = (nb >= 0) & graph.alive[jnp.clip(nb, 0)]
        xv, hit = _gather_tiered(graph, cache, nb)
        d = _sqdist(xv, q)
        # drop invalid + already-in-pool duplicates
        in_pool = (nb[:, None] == ids[None, :]).any(1)
        d = jnp.where(valid & ~in_pool, d, INF)

        all_ids = jnp.concatenate([ids, nb])
        all_d = jnp.concatenate([dists, d])
        all_vis = jnp.concatenate([visited, jnp.zeros((R,), bool)])
        keep = jnp.argsort(all_d)[:L]
        ids, dists, visited = all_ids[keep], all_d[keep], all_vis[keep]

        acc_ids = acc_ids.at[it].set(jnp.where(valid, nb, -1))
        acc_hit = acc_hit.at[it].set(hit & valid)
        return it + 1, ids, dists, visited, acc_ids, acc_hit

    it, ids, dists, visited, acc_ids, acc_hit = jax.lax.while_loop(
        cond, body, (jnp.int32(0), ids0, dist0, visited0, acc_ids0, acc_hit0))

    topk_ids = jnp.where(jnp.isfinite(dists[:sp.k]), ids[:sp.k], -1)
    return SearchResult(topk_ids, dists[:sp.k],
                        acc_ids.reshape(-1), acc_hit.reshape(-1), it)


@partial(jax.jit, static_argnames=("sp",))
def search_batch(state: IndexState, queries, key, sp: SearchParams
                 ) -> SearchResult:
    """Batched ANNS. queries [B, D]. Entry points are random (paper §4.2:
    GPU-friendly, no seed maintenance under updates)."""
    B = queries.shape[0]
    n = jnp.maximum(state.graph.n, 1)
    entries = jax.random.randint(key, (B, sp.pool), 0, n, dtype=jnp.int32)
    res = jax.vmap(lambda q, e: _search_one(state.graph, state.cache, q, e, sp)
                   )(queries.astype(jnp.float32), entries)
    return res


# ---------------------------------------------------------------------------
# Three-tier search: CPU traversal + disk IO, device distance compute
# ---------------------------------------------------------------------------

@jax.jit
def _batch_sqdist(x, q):
    """[B, R, D] gathered rows vs [B, D] queries -> [B, R] fp32 distances.
    One fixed-shape jitted GEMV per expansion — the device-compute arm the
    async prefetcher overlaps disk reads against (paper §4.4)."""
    diff = x - q[:, None, :]
    return jnp.einsum("brd,brd->br", diff, diff,
                      preferred_element_type=jnp.float32)


def dedup_mask(a):
    """Per-row duplicate flags for an int array [B, C] (any one occurrence
    survives). Shared by the tiered search/update paths."""
    order = np.argsort(a, axis=1, kind="stable")
    srt = np.take_along_axis(a, order, axis=1)
    dup_sorted = np.concatenate(
        [np.zeros((a.shape[0], 1), bool), srt[:, 1:] == srt[:, :-1]], axis=1)
    dup = np.empty_like(dup_sorted)
    np.put_along_axis(dup, order, dup_sorted, axis=1)
    return dup


class TieredSearchResult(NamedTuple):
    ids: np.ndarray       # [B, k]
    dists: np.ndarray     # [B, k]
    acc_ids: np.ndarray   # [B, I*R] accessed vertex ids (-1 pad)
    acc_hit: np.ndarray   # [B, I*R] device-cache-hit flags
    iters: int


def _cascade_vectors(ids_flat, h2d, cache_vec, store, f_lam):
    """Resolve vectors for a flat id batch through the hierarchy:
    device cache (mirror) -> host window -> disk. Returns (vectors
    [n, D] fp32, device_hit [n] bool). Invalid ids (<0) read row 0 of
    whatever tier and must be masked by the caller."""
    cid = np.clip(ids_flat, 0, None)
    slot = h2d[cid]
    dev_hit = (slot >= 0) & (ids_flat >= 0)
    vec = np.zeros((len(ids_flat), store.disk.dim), np.float32)
    if dev_hit.any():
        vec[dev_hit] = cache_vec[slot[dev_hit]]
    rest = ~dev_hit & (ids_flat >= 0)   # pad lanes never reach the store
    if rest.any():
        uniq, inv = np.unique(cid[rest], return_inverse=True)
        uv, _ = store.fetch(uniq, f_lam)
        vec[rest] = uv[inv]
    return vec, dev_hit


def search_tiered(backend, cache_mirror, queries, seed, sp: SearchParams,
                  *, f_lam=None,
                  prefetch_budget: int = 0) -> TieredSearchResult:
    """Greedy beam search over a disk-backed graph (paper Algorithm 1 in
    its GPU-CPU-disk form). The host owns the traversal and residency, the
    device evaluates distances batch-at-a-time; every vector read cascades
    device cache -> host window -> disk, and (optionally) the predicted
    next frontier is enqueued to the store's async prefetcher ranked by
    F_λ so disk latency hides behind the next distance batch.

    backend: ``tiers.TieredBackend``; cache_mirror: ``cache.HostPlacement``
    (readers snapshot its arrays once, see HostPlacement docs).
    """
    store = backend.store
    alive = backend.alive
    # ONE snapshot read: h2d and vectors must come from the same publish
    # (see cache.CacheView) or a concurrent placement pass could pair an
    # old mapping with new payloads
    view = cache_mirror.view
    h2d, cache_vec = view.h2d, view.vectors
    if f_lam is None:   # callers doing several passes precompute O(N) once
        f_lam = cache_mirror.scores(backend.e_in)

    queries = np.asarray(queries, np.float32)
    B, D = queries.shape
    L, R, I, k = sp.pool, backend.degree, sp.max_iters, sp.k
    n = max(backend.n, 1)
    rng = np.random.default_rng(seed)
    qj = jnp.asarray(queries)

    # entry pool: random entries (paper §4.2 — no seed maintenance)
    pool_ids = rng.integers(0, n, (B, L))
    ev, _ = _cascade_vectors(pool_ids.reshape(-1), h2d, cache_vec, store,
                             f_lam)
    pool_d = np.array(_batch_sqdist(jnp.asarray(ev.reshape(B, L, D)), qj))
    pool_d[~alive[pool_ids]] = np.inf
    pool_d[dedup_mask(pool_ids)] = np.inf   # dedup random entries
    o = np.argsort(pool_d, axis=1, kind="stable")
    pool_ids = np.take_along_axis(pool_ids, o, axis=1)
    pool_d = np.take_along_axis(pool_d, o, axis=1)
    visited = np.zeros((B, L), bool)

    acc_ids = np.full((B, I, R), -1, np.int32)
    acc_hit = np.zeros((B, I, R), bool)
    lanes = np.arange(B)
    it = 0
    for it in range(I):
        sel = np.where(visited | ~np.isfinite(pool_d), np.inf, pool_d)
        best = np.argmin(sel, axis=1)
        active = np.isfinite(sel[lanes, best])
        if not active.any():
            break
        curr = np.where(active, pool_ids[lanes, best], -1)
        visited[lanes[active], best[active]] = True

        # frontier rows come from the capacity tier (topology lives on
        # host/disk only; the device cache stores vectors)
        ucur = np.unique(curr[active])
        _, urows = store.fetch(ucur, f_lam)
        lut = {int(v): i for i, v in enumerate(ucur)}
        nb = np.full((B, R), -1, np.int32)
        nb[active] = urows[[lut[int(v)] for v in curr[active]]]

        valid = (nb >= 0) & alive[np.clip(nb, 0, None)]
        xv, dev_hit = _cascade_vectors(nb.reshape(-1), h2d, cache_vec,
                                       store, f_lam)
        d = np.asarray(_batch_sqdist(jnp.asarray(xv.reshape(B, R, D)), qj))
        in_pool = (nb[:, :, None] == pool_ids[:, None, :]).any(-1)
        d = np.where(valid & ~in_pool, d, np.inf)

        acc_ids[:, it] = np.where(valid, nb, -1)
        acc_hit[:, it] = dev_hit.reshape(B, R) & valid

        all_ids = np.concatenate([pool_ids, nb], axis=1)
        all_d = np.concatenate([pool_d, d], axis=1)
        all_vis = np.concatenate([visited, np.zeros((B, R), bool)], axis=1)
        keep = np.argsort(all_d, axis=1, kind="stable")[:, :L]
        pool_ids = np.take_along_axis(all_ids, keep, axis=1)
        pool_d = np.take_along_axis(all_d, keep, axis=1)
        visited = np.take_along_axis(all_vis, keep, axis=1)

        if prefetch_budget > 0:
            # predicted next frontier: best unvisited candidates; enqueue
            # the hottest (top-F_λ) non-resident ones so their rows reach
            # the host window while the next distance batch computes
            head = pool_ids[:, :4].reshape(-1)
            head = head[head >= 0]
            cand = np.unique(head[store.loc[head] < 0])
            if cand.size:
                hot = cand[np.argsort(-f_lam[cand])][:prefetch_budget]
                store.prefetch(hot, f_lam)

    topk_ids = np.where(np.isfinite(pool_d[:, :k]), pool_ids[:, :k], -1)
    return TieredSearchResult(topk_ids.astype(np.int32), pool_d[:, :k],
                              acc_ids.reshape(B, -1),
                              acc_hit.reshape(B, -1), it + 1)


def brute_force_topk(graph: GraphState, queries, k):
    """Exact ground truth over alive vectors (recall oracle)."""
    d = (jnp.sum(queries ** 2, 1, keepdims=True)
         - 2.0 * queries @ graph.vectors.T
         + jnp.sum(graph.vectors ** 2, 1)[None, :])
    d = jnp.where(graph.alive[None, :], d, INF)
    nd, idx = jax.lax.top_k(-d, k)
    return idx, -nd


def recall_at_k(found_ids, true_ids):
    """found/true [B, k] -> mean fraction of true ids found."""
    hits = (found_ids[:, :, None] == true_ids[:, None, :]).any(1)
    return jnp.mean(hits.astype(jnp.float32))
