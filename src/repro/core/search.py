"""ANNS with CPU-GPU co-processing (paper Algorithm 1), TPU adaptation.

Both serving paths run through ONE **hop-batched frontier executor**: a
beam of ``sp.beam`` frontier candidates is expanded per *round*, their
neighborhoods are resolved in bulk through the tier cascade, and a single
jitted gather + distance + top-k-merge dispatch covers every hop in the
beam — the paper's CUDA multi-stream coordination of batched frontier
expansions (§4/§6) mapped onto XLA dispatch amortization:

* **device arm** (``search_batch``): the capacity tier is device-resident,
  so all rounds fuse into one jitted program (``lax.while_loop`` over
  rounds); distances come from the ``kernels/l2_gather`` arm with the
  device-cache overlay.
* **tiered arm** (``search_tiered``): the host owns traversal + residency
  over the disk-backed store; each round issues one bulk row fetch, one
  vector cascade, and ONE jitted distance+merge dispatch — so device
  dispatches per query drop from ``max_iters`` to ``ceil(max_iters/beam)``
  — while the store's async prefetcher overlaps predicted next-frontier
  disk reads against the in-flight dispatch (multi-stream pipelining,
  paper §4.4).

Every expansion consults the cache mapping table; hits read the bandwidth
tier, misses the capacity tier, and both are logged for the post-batch
WAVP pass (cache.py) which amortizes transfer cost over the batch.

Returns per-query top-k plus the access/hit logs consumed by
``repro.core.cache.apply_wavp`` / ``apply_wavp_host``.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import CacheState, GraphState, IndexState, SearchParams
from repro.kernels.ops import gather_l2

INF = jnp.float32(jnp.inf)


class SearchResult(NamedTuple):
    ids: jax.Array        # [B, k]
    dists: jax.Array      # [B, k]
    acc_ids: jax.Array    # [B, rounds*beam*R] accessed vertex ids (-1 pad)
    acc_hit: jax.Array    # [B, rounds*beam*R] cache-hit flags
    iters: jax.Array      # [B] expansion rounds used


def _n_rounds(sp: SearchParams) -> int:
    """Round budget: ceil(total hop budget / beam width)."""
    beam = max(1, sp.beam)
    return max(1, -(-sp.max_iters // beam))


# ---------------------------------------------------------------------------
# Shared executor core (pure jnp, batched over queries). Both arms build
# their jitted dispatch out of these three pieces.
# ---------------------------------------------------------------------------

def dup_mask_jnp(a):
    """Later-occurrence duplicate flags for id batches [..., C] (the first
    occurrence survives). This is the cross-tier round dedup: the same id
    arriving from different tiers or different beam slots in one round
    collapses to a single candidate, so it can never occupy multiple pool
    slots. Sort-based (O(C log C), the jnp twin of ``dedup_mask``): a
    pairwise-equality matrix would be O(C²) in beam·degree per round."""
    order = jnp.argsort(a, axis=-1, stable=True)
    srt = jnp.take_along_axis(a, order, axis=-1)
    dup_sorted = jnp.concatenate(
        [jnp.zeros(srt.shape[:-1] + (1,), bool),
         srt[..., 1:] == srt[..., :-1]], axis=-1)
    inv = jnp.argsort(order, axis=-1, stable=True)
    return jnp.take_along_axis(dup_sorted, inv, axis=-1)


def select_frontier(pool_ids, pool_d, visited, beam: int):
    """Pick the best ``beam`` unvisited finite pool slots per query and
    mark them visited. Returns (curr [B, beam] ids, -1 for idle lanes;
    visited')."""
    sel = jnp.where(visited | ~jnp.isfinite(pool_d), INF, pool_d)
    order = jnp.argsort(sel, axis=1, stable=True)[:, :beam]
    ok = jnp.isfinite(jnp.take_along_axis(sel, order, axis=1))
    curr = jnp.where(ok, jnp.take_along_axis(pool_ids, order, axis=1), -1)
    upd = jnp.take_along_axis(visited, order, axis=1) | ok
    visited = jax.vmap(lambda v, o, u: v.at[o].set(u))(visited, order, upd)
    return curr, visited


def merge_round(pool_ids, pool_d, visited, cand_ids, cand_d):
    """Merge one round's candidate batch [B, C] into the pool [B, L].
    ``cand_d`` must already be INF on invalid/dead lanes; duplicates
    within the batch and ids already pooled are dropped here, preserving
    the pool's one-slot-per-id invariant."""
    L = pool_ids.shape[1]
    in_pool = (cand_ids[:, :, None] == pool_ids[:, None, :]).any(-1)
    cand_d = jnp.where(in_pool | dup_mask_jnp(cand_ids), INF, cand_d)
    all_ids = jnp.concatenate([pool_ids, cand_ids], axis=1)
    all_d = jnp.concatenate([pool_d, cand_d], axis=1)
    all_vis = jnp.concatenate(
        [visited, jnp.zeros(cand_ids.shape, bool)], axis=1)
    keep = jnp.argsort(all_d, axis=1, stable=True)[:, :L]
    return (jnp.take_along_axis(all_ids, keep, axis=1),
            jnp.take_along_axis(all_d, keep, axis=1),
            jnp.take_along_axis(all_vis, keep, axis=1))


def init_pool(entry_ids, entry_d):
    """Sort the (deduped) entry pool into executor state."""
    d = jnp.where(dup_mask_jnp(entry_ids), INF, entry_d)
    order = jnp.argsort(d, axis=1, stable=True)
    return (jnp.take_along_axis(entry_ids, order, axis=1),
            jnp.take_along_axis(d, order, axis=1),
            jnp.zeros(entry_ids.shape, bool))


# ---------------------------------------------------------------------------
# Device arm: in-memory tiers, one fused jitted program
# ---------------------------------------------------------------------------

def _device_distances(graph: GraphState, cache: CacheState, ids, queries):
    """Distances for an id batch [B, C] through the two device tiers: the
    ``l2_gather`` kernel arm against the capacity table, overlaid with the
    bandwidth-tier copy on cache hits. Invalid ids (< 0) come back +inf.
    Returns (dists [B, C] fp32, device_hit [B, C])."""
    cid = jnp.clip(ids, 0)
    slot = cache.h2d[cid]
    hit = (slot >= 0) & (ids >= 0)
    d_cap = gather_l2(graph.vectors, ids, queries)
    d_dev = gather_l2(cache.vectors, jnp.where(hit, slot, -1), queries)
    return jnp.where(hit, d_dev, d_cap), hit


def _frontier_search(graph: GraphState, cache: CacheState, queries, entries,
                     sp: SearchParams) -> SearchResult:
    """Hop-batched frontier executor, device arm (traceable; callers jit).
    queries [B, D], entries [B, L]."""
    B = queries.shape[0]
    L, R = sp.pool, graph.degree
    beam = max(1, sp.beam)
    rounds = _n_rounds(sp)
    C = beam * R
    queries = queries.astype(graph.vectors.dtype)

    d0, _ = _device_distances(graph, cache, entries, queries)
    d0 = jnp.where(graph.alive[jnp.clip(entries, 0)] & (entries >= 0),
                   d0, INF)
    pool_ids0, pool_d0, visited0 = init_pool(entries, d0)

    acc_ids0 = jnp.full((B, rounds, C), -1, jnp.int32)
    acc_hit0 = jnp.zeros((B, rounds, C), bool)
    iters0 = jnp.zeros((B,), jnp.int32)

    def cond(s):
        r, ids, dists, visited, *_ = s
        return (r < rounds) & ((~visited) & jnp.isfinite(dists)).any()

    def body(s):
        r, ids, dists, visited, acc_ids, acc_hit, iters = s
        active = ((~visited) & jnp.isfinite(dists)).any(1)          # [B]
        curr, visited = select_frontier(ids, dists, visited, beam)
        nb = graph.nbrs[jnp.clip(curr, 0)]                # [B, beam, R]
        nb = jnp.where(curr[..., None] >= 0, nb, -1).reshape(B, C)
        valid = (nb >= 0) & graph.alive[jnp.clip(nb, 0)]
        d, hit = _device_distances(graph, cache, nb, queries)
        d = jnp.where(valid, d, INF)
        ids, dists, visited = merge_round(ids, dists, visited, nb, d)
        acc_ids = acc_ids.at[:, r].set(jnp.where(valid, nb, -1))
        acc_hit = acc_hit.at[:, r].set(hit & valid)
        return (r + 1, ids, dists, visited, acc_ids, acc_hit,
                iters + active.astype(jnp.int32))

    _, ids, dists, _, acc_ids, acc_hit, iters = jax.lax.while_loop(
        cond, body,
        (jnp.int32(0), pool_ids0, pool_d0, visited0, acc_ids0, acc_hit0,
         iters0))

    topk_ids = jnp.where(jnp.isfinite(dists[:, :sp.k]), ids[:, :sp.k], -1)
    return SearchResult(topk_ids, dists[:, :sp.k],
                        acc_ids.reshape(B, -1), acc_hit.reshape(B, -1),
                        iters)


@partial(jax.jit, static_argnames=("sp",))
def frontier_search(state: IndexState, queries, entries, sp: SearchParams
                    ) -> SearchResult:
    """Jitted executor entry with caller-chosen entry points (parity tests
    and update paths pass deterministic entries here)."""
    return _frontier_search(state.graph, state.cache,
                            queries.astype(jnp.float32), entries, sp)


@partial(jax.jit, static_argnames=("sp",))
def search_batch(state: IndexState, queries, key, sp: SearchParams
                 ) -> SearchResult:
    """Batched ANNS — thin entry point over the frontier executor.
    queries [B, D]. Entry points are random (paper §4.2: GPU-friendly, no
    seed maintenance under updates)."""
    B = queries.shape[0]
    n = jnp.maximum(state.graph.n, 1)
    entries = jax.random.randint(key, (B, sp.pool), 0, n, dtype=jnp.int32)
    return _frontier_search(state.graph, state.cache,
                            queries.astype(jnp.float32), entries, sp)


# ---------------------------------------------------------------------------
# Tiered arm: CPU traversal + disk IO, one device dispatch per round
# ---------------------------------------------------------------------------

@jax.jit
def _batch_sqdist(x, q):
    """[B, C, D] gathered rows vs [B, D] queries -> [B, C] fp32 distances."""
    diff = x - q[:, None, :]
    return jnp.einsum("brd,brd->br", diff, diff,
                      preferred_element_type=jnp.float32)


@partial(jax.jit, static_argnames=("beam",))
def _tiered_entry_dispatch(entry_ids, entry_vecs, entry_valid, queries,
                           beam):
    """Entry-pool distances + dedup + sort + first frontier selection:
    the first of the per-round dispatches (shares the executor core with
    the device arm). Pool state stays device-resident across rounds; only
    the tiny [B, beam] frontier id matrix crosses back to the host."""
    d = _batch_sqdist(entry_vecs, queries)
    d = jnp.where(entry_valid, d, INF)
    pool_ids, pool_d, visited = init_pool(entry_ids, d)
    curr, visited = select_frontier(pool_ids, pool_d, visited, beam)
    return pool_ids, pool_d, visited, curr


@partial(jax.jit, static_argnames=("beam",))
def _tiered_round_dispatch(pool_ids, pool_d, visited, cand_ids, cand_vecs,
                           cand_valid, queries, beam):
    """ONE jitted gather+distance+topk-merge(+next frontier selection)
    dispatch covering every hop in the round's beam — the tiered arm of
    the shared executor. Inputs/outputs holding pool state are device
    arrays that never round-trip through the host."""
    d = _batch_sqdist(cand_vecs, queries)
    d = jnp.where(cand_valid, d, INF)
    pool_ids, pool_d, visited = merge_round(pool_ids, pool_d, visited,
                                            cand_ids, d)
    curr, visited = select_frontier(pool_ids, pool_d, visited, beam)
    return pool_ids, pool_d, visited, curr


def dedup_mask(a):
    """Per-row duplicate flags for an int array [B, C] (any one occurrence
    survives). Host twin of ``dup_mask_jnp``; shared by the tiered update
    paths."""
    order = np.argsort(a, axis=1, kind="stable")
    srt = np.take_along_axis(a, order, axis=1)
    dup_sorted = np.concatenate(
        [np.zeros((a.shape[0], 1), bool), srt[:, 1:] == srt[:, :-1]], axis=1)
    dup = np.empty_like(dup_sorted)
    np.put_along_axis(dup, order, dup_sorted, axis=1)
    return dup


class TieredSearchResult(NamedTuple):
    ids: np.ndarray       # [B, k]
    dists: np.ndarray     # [B, k]
    acc_ids: np.ndarray   # [B, rounds*beam*R] accessed vertex ids (-1 pad)
    acc_hit: np.ndarray   # [B, rounds*beam*R] device-cache-hit flags
    iters: int            # expansion rounds executed
    dispatches: int       # jitted device dispatches issued (1 + iters)


def _cascade_vectors(ids_flat, h2d, cache_vec, store, f_lam):
    """Resolve vectors for a flat id batch through the hierarchy:
    device cache (mirror) -> host window -> disk. Returns (vectors
    [n, D] fp32, device_hit [n] bool). Invalid ids (<0) read row 0 of
    whatever tier and must be masked by the caller."""
    cid = np.clip(ids_flat, 0, None)
    slot = h2d[cid]
    dev_hit = (slot >= 0) & (ids_flat >= 0)
    vec = np.zeros((len(ids_flat), store.disk.dim), np.float32)
    if dev_hit.any():
        vec[dev_hit] = cache_vec[slot[dev_hit]]
    rest = ~dev_hit & (ids_flat >= 0)   # pad lanes never reach the store
    if rest.any():
        uniq, inv = np.unique(cid[rest], return_inverse=True)
        uv, _ = store.fetch(uniq, f_lam)
        vec[rest] = uv[inv]
    return vec, dev_hit


def _predict_prefetch(store, nb, valid, f_lam, budget, probe=8):
    """Predicted next-frontier prefetch (paper §4.4 multi-stream overlap):
    the rows of this round's candidates are already window-resident (the
    cascade promoted them), so peeking the hottest candidates' adjacency
    is cheap; their non-resident neighbors are what the *next* round will
    need from disk. Called while the round's device dispatch is in
    flight, so the background disk reads overlap device compute."""
    cand = np.unique(nb[valid])
    if not cand.size:
        return
    if cand.size > probe:     # argpartition: this runs once per round
        cand = cand[np.argpartition(-f_lam[cand], probe - 1)[:probe]]
    hrows = store.peek_rows(cand)
    nxt = np.unique(hrows[hrows >= 0])
    nxt = nxt[store.loc[nxt] < 0]
    if nxt.size:
        if nxt.size > budget:
            nxt = nxt[np.argpartition(-f_lam[nxt], budget - 1)[:budget]]
        store.prefetch(nxt, f_lam)


def search_tiered(backend, cache_mirror, queries, seed, sp: SearchParams,
                  *, f_lam=None, prefetch_budget: int = 0,
                  entry_ids=None) -> TieredSearchResult:
    """Hop-batched frontier search over a disk-backed graph (paper
    Algorithm 1 in its GPU-CPU-disk form) — the tiered arm of the shared
    executor. The host owns traversal and residency; each round expands a
    beam of ``sp.beam`` frontier candidates, resolves their neighborhoods
    through the cascade device cache -> host window -> disk in bulk, and
    issues ONE jitted distance+merge dispatch, with the predicted next
    frontier enqueued to the store's async prefetcher while that dispatch
    is in flight.

    backend: ``tiers.TieredBackend``; cache_mirror: ``cache.HostPlacement``
    (readers snapshot its arrays once, see HostPlacement docs).
    ``entry_ids`` [B, pool] overrides the random entry pool (parity tests).
    """
    store = backend.store
    alive = backend.alive
    # ONE snapshot read: h2d and vectors must come from the same publish
    # (see cache.CacheView) or a concurrent placement pass could pair an
    # old mapping with new payloads
    view = cache_mirror.view
    h2d, cache_vec = view.h2d, view.vectors
    if f_lam is None:   # callers doing several passes precompute O(N) once
        f_lam = cache_mirror.scores(backend.e_in)

    queries = np.asarray(queries, np.float32)
    B, D = queries.shape
    L, R, k = sp.pool, backend.degree, sp.k
    beam = max(1, sp.beam)
    rounds = _n_rounds(sp)
    C = beam * R
    n = max(backend.n, 1)
    qj = jnp.asarray(queries)
    if entry_ids is None:
        rng = np.random.default_rng(seed)
        entry_ids = rng.integers(0, n, (B, L))
    entry_ids = np.asarray(entry_ids, np.int64)

    # entry pool: one cascade + one entry dispatch
    ev, _ = _cascade_vectors(entry_ids.reshape(-1), h2d, cache_vec, store,
                             f_lam)
    pool_ids, pool_d, visited, curr_j = _tiered_entry_dispatch(
        jnp.asarray(entry_ids, jnp.int32), jnp.asarray(ev.reshape(B, L, D)),
        jnp.asarray(alive[entry_ids]), qj, beam)
    dispatches = 1
    curr = np.asarray(curr_j)                 # [B, beam], -1 = idle lane

    acc_ids = np.full((B, rounds, C), -1, np.int32)
    acc_hit = np.zeros((B, rounds, C), bool)
    it = 0
    for _ in range(rounds):
        ok = curr >= 0
        if not ok.any():
            break
        # ONE bulk row fetch for the whole beam (topology lives on
        # host/disk only; the device cache stores vectors)
        ucur = np.unique(curr[ok])
        _, urows = store.fetch(ucur, f_lam)
        nb = np.full((B, beam, R), -1, np.int32)
        # searchsorted over the (sorted) unique ids: O(|curr| log |ucur|),
        # no O(dataset) scratch on the per-round hot path
        nb[ok] = urows[np.searchsorted(ucur, curr[ok])]
        nb = nb.reshape(B, C)

        valid = (nb >= 0) & alive[np.clip(nb, 0, None)]
        xv, dev_hit = _cascade_vectors(nb.reshape(-1), h2d, cache_vec,
                                       store, f_lam)
        # launch the round's single device dispatch (async); pool state
        # stays device-resident, only `curr` crosses back. The prefetch
        # prediction below overlaps with the in-flight dispatch.
        pool_ids, pool_d, visited, curr_j = _tiered_round_dispatch(
            pool_ids, pool_d, visited, jnp.asarray(nb),
            jnp.asarray(xv.reshape(B, C, D)), jnp.asarray(valid), qj, beam)
        dispatches += 1
        acc_ids[:, it] = np.where(valid, nb, -1)
        acc_hit[:, it] = dev_hit.reshape(B, C) & valid
        if prefetch_budget > 0:
            _predict_prefetch(store, nb, valid, f_lam, prefetch_budget)
        curr = np.asarray(curr_j)             # sync point for the round
        it += 1

    pool_ids, pool_d = np.asarray(pool_ids), np.asarray(pool_d)
    topk_ids = np.where(np.isfinite(pool_d[:, :k]), pool_ids[:, :k], -1)
    return TieredSearchResult(topk_ids.astype(np.int32), pool_d[:, :k],
                              acc_ids.reshape(B, -1),
                              acc_hit.reshape(B, -1), it, dispatches)


def brute_force_topk(graph: GraphState, queries, k):
    """Exact ground truth over alive vectors (recall oracle)."""
    d = (jnp.sum(queries ** 2, 1, keepdims=True)
         - 2.0 * queries @ graph.vectors.T
         + jnp.sum(graph.vectors ** 2, 1)[None, :])
    d = jnp.where(graph.alive[None, :], d, INF)
    nd, idx = jax.lax.top_k(-d, k)
    return idx, -nd


def recall_at_k(found_ids, true_ids):
    """found/true [B, k] -> mean fraction of true ids found."""
    hits = (found_ids[:, :, None] == true_ids[:, None, :]).any(1)
    return jnp.mean(hits.astype(jnp.float32))
