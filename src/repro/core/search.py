"""ANNS with CPU-GPU co-processing (paper Algorithm 1), TPU adaptation.

Both serving paths run through ONE **hop-batched frontier executor**: a
beam of ``sp.beam`` frontier candidates is expanded per *round*, their
neighborhoods are resolved in bulk through the tier cascade, and a single
jitted gather + distance + top-k-merge dispatch covers every hop in the
beam — the paper's CUDA multi-stream coordination of batched frontier
expansions (§4/§6) mapped onto XLA dispatch amortization:

* **device arm** (``search_batch``): the capacity tier is device-resident,
  so all rounds fuse into one jitted program (``lax.while_loop`` over
  rounds); distances come from the ``kernels/l2_gather`` arm with the
  device-cache overlay.
* **tiered arm** (``search_tiered``): the host owns traversal + residency
  over the disk-backed store, and runs as a **two-stage speculative
  pipeline** (paper §4.4 multi-stream overlap): while round N's single
  jitted distance+merge dispatch is in flight, the host predicts round
  N+1's frontier (entry stage: exact host distances; later rounds: the
  WAVP F_λ probe), stages the predicted rows and their neighborhoods'
  vectors, and enqueues disk prefetch one hop further. When the real
  frontier reads back, staged ids feed the next dispatch immediately and
  only mispredicted ids cost a delta fetch — the per-round read-back sync
  no longer serializes host IO behind device compute.

XLA-CPU note: a variadic (key, payload) sort — what ``jnp.argsort``
lowers to — costs ~10x a single-operand sort on this backend, and the
executor's merge used three of them per round. The core ops are built on
``lax.top_k`` (stable: equal values keep ascending-index order, matching
stable-argsort semantics) plus, for duplicate detection, ONE single-key
sort of ids packed with their lane index; semantics are unchanged (the
parity suite pins them against the per-hop reference).

Every expansion consults the cache mapping table; hits read the bandwidth
tier, misses the capacity tier, and both are logged for the post-batch
WAVP pass (cache.py) which amortizes transfer cost over the batch.

Returns per-query top-k plus the access/hit logs consumed by
``repro.core.cache.apply_wavp`` / ``apply_wavp_host``.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quant import adc_lut
from repro.core.types import CacheState, GraphState, IndexState, SearchParams
from repro.kernels.ops import adc_gather, gather_l2, gather_rows

INF = jnp.float32(jnp.inf)


class SearchResult(NamedTuple):
    ids: jax.Array        # [B, k]
    dists: jax.Array      # [B, k]
    acc_ids: jax.Array    # [B, rounds*beam*R] accessed vertex ids (-1 pad)
    acc_hit: jax.Array    # [B, rounds*beam*R] cache-hit flags
    iters: jax.Array      # [B] expansion rounds used


def _n_rounds(sp: SearchParams) -> int:
    """Round budget: ceil(total hop budget / beam width)."""
    beam = max(1, sp.beam)
    return max(1, -(-sp.max_iters // beam))


# ---------------------------------------------------------------------------
# Shared executor core (pure jnp, batched over queries). Both arms build
# their jitted dispatch out of these three pieces.
# ---------------------------------------------------------------------------

def _lane_bits(width: int) -> int:
    return max(1, (width - 1).bit_length())


def _packable(id_bound, width: int) -> bool:
    """True when (id, lane) pairs over ``width`` lanes pack exactly into an
    int32 key: ids below ``id_bound`` shifted left still fit, and -1 pad
    lanes keep distinct negative keys (arithmetic shift recovers the id)."""
    return (id_bound is not None
            and int(id_bound) < (1 << (31 - _lane_bits(width))))


def _take(a, idx):
    return jnp.take_along_axis(a, idx, axis=-1)


def dup_mask_jnp(a, id_bound=None):
    """Later-occurrence duplicate flags for id batches [..., C] (the first
    occurrence survives). This is the cross-tier round dedup: the same id
    arriving from different tiers or different beam slots in one round
    collapses to a single candidate, so it can never occupy multiple pool
    slots. When ``id_bound`` (exclusive id upper bound, static) packs, the
    sort is ONE single-operand key sort of ``id·2^bits + lane`` — ~10x
    cheaper than the argsort pair-sort fallback on the CPU backend, with
    identical semantics (keys are unique, so sort stability is moot)."""
    C = a.shape[-1]
    if _packable(id_bound, C):
        bits = _lane_bits(C)
        lead = a.shape[:-1]
        flat = a.reshape((-1, C)).astype(jnp.int32)
        iota = jnp.arange(C, dtype=jnp.int32)
        s = jnp.sort((flat << bits) | iota, axis=-1)
        sid = s >> bits                      # arithmetic shift: -1 pads ok
        dup_sorted = jnp.concatenate(
            [jnp.zeros((flat.shape[0], 1), bool),
             sid[:, 1:] == sid[:, :-1]], axis=-1)
        pos = s & ((1 << bits) - 1)
        bidx = jnp.arange(flat.shape[0], dtype=jnp.int32)[:, None]
        out = jnp.zeros(flat.shape, bool).at[bidx, pos].set(dup_sorted)
        return out.reshape(lead + (C,))
    order = jnp.argsort(a, axis=-1, stable=True)
    srt = jnp.take_along_axis(a, order, axis=-1)
    dup_sorted = jnp.concatenate(
        [jnp.zeros(srt.shape[:-1] + (1,), bool),
         srt[..., 1:] == srt[..., :-1]], axis=-1)
    inv = jnp.argsort(order, axis=-1, stable=True)
    return jnp.take_along_axis(dup_sorted, inv, axis=-1)


def select_frontier(pool_ids, pool_d, visited, beam: int):
    """Pick the best ``beam`` unvisited finite pool slots per query and
    mark them visited. Returns (curr [B, beam] ids, -1 for idle lanes;
    visited'). ``lax.top_k`` keeps stable-argsort order (ties resolve to
    the lower index)."""
    sel = jnp.where(visited | ~jnp.isfinite(pool_d), INF, pool_d)
    negd, order = jax.lax.top_k(-sel, beam)
    ok = jnp.isfinite(negd)
    curr = jnp.where(ok, _take(pool_ids, order), -1)
    upd = _take(visited, order) | ok
    bidx = jnp.arange(pool_ids.shape[0], dtype=jnp.int32)[:, None]
    visited = visited.at[bidx, order].set(upd)
    return curr, visited


def merge_round(pool_ids, pool_d, visited, cand_ids, cand_d, id_bound=None):
    """Merge one round's candidate batch [B, C] into the pool [B, L].
    ``cand_d`` must already be INF on invalid/dead lanes; duplicates
    within the batch and ids already pooled are dropped here, preserving
    the pool's one-slot-per-id invariant.

    Fast path: pool and candidate ids concatenate into ONE packed-key
    sort — within a sorted id run, pool lanes (lane < L) precede
    candidate lanes, so a lane is a duplicate exactly when it continues
    a run (already pooled OR repeated in the batch). The top-L selection
    (``lax.top_k``) then runs directly in id-sorted lane order: gathers
    only, no scatter back to original lanes. Equal finite distances on
    *distinct* ids may tie-break differently from original-lane order —
    for exact duplicates (the only systematic ties) the survivor set is
    unchanged, so pool contents are unaffected on non-degenerate data.
    The argsort-era O(C·L) compare + pair-sorts remain as the fallback
    for unpackable id ranges."""
    L = pool_ids.shape[1]
    all_ids = jnp.concatenate([pool_ids, cand_ids], axis=1)
    T = all_ids.shape[1]
    all_vis = jnp.concatenate(
        [visited, jnp.zeros(cand_ids.shape, bool)], axis=1)
    if _packable(id_bound, T):
        bits = _lane_bits(T)
        iota = jnp.arange(T, dtype=jnp.int32)
        s = jnp.sort((all_ids.astype(jnp.int32) << bits) | iota, axis=-1)
        sid = s >> bits
        pos = s & ((1 << bits) - 1)
        cont = jnp.concatenate(
            [jnp.zeros((s.shape[0], 1), bool), sid[:, 1:] == sid[:, :-1]],
            axis=-1)
        all_d = jnp.concatenate([pool_d, cand_d], axis=1)
        d_srt = jnp.where(cont & (pos >= L), INF, _take(all_d, pos))
        _, keep = jax.lax.top_k(-d_srt, L)
        return (_take(sid, keep), _take(d_srt, keep),
                _take(all_vis, _take(pos, keep)))
    in_pool = (cand_ids[:, :, None] == pool_ids[:, None, :]).any(-1)
    cand_d = jnp.where(in_pool | dup_mask_jnp(cand_ids, id_bound),
                       INF, cand_d)
    all_d = jnp.concatenate([pool_d, cand_d], axis=1)
    _, keep = jax.lax.top_k(-all_d, L)
    return _take(all_ids, keep), _take(all_d, keep), _take(all_vis, keep)


def init_pool(entry_ids, entry_d, id_bound=None):
    """Sort the (deduped) entry pool into executor state."""
    d = jnp.where(dup_mask_jnp(entry_ids, id_bound), INF, entry_d)
    _, order = jax.lax.top_k(-d, d.shape[1])
    return (_take(entry_ids, order), _take(d, order),
            jnp.zeros(entry_ids.shape, bool))


def _run_fused_rounds(state, r_stop, beam, id_bound, row_fn, dist_fn):
    """The ONE fused multi-round executor core both arms share: a
    ``lax.while_loop`` running row gather -> distance -> topk merge ->
    next-frontier select entirely on device, round after round, until the
    round budget ``r_stop`` (a traced operand: callers re-enter without a
    recompile), the pool runs dry, or a row lookup stalls.

    ``state`` carry: (r, pool_ids, pool_d, visited, curr, acc_ids
    [B, rounds, C], acc_hit, iters [B], stall). The frontier ``curr`` is
    selected at the END of each body (entry select happens outside), so
    the loop condition reads residual work straight off the idle-lane
    sentinel — same gating as the old device-arm loop, where the select
    ran at the top of the body.

    ``row_fn(curr [B, beam]) -> (nb [B, beam, R], resident [B, beam])``
    resolves frontier adjacency. The device arm's capacity tier is always
    resident; the tiered arm gathers through the device topology cache
    (``kernels/row_gather``) and reports non-resident frontier ids. Any
    true (id >= 0) non-resident lane STALLS the loop: the body's updates
    are discarded wholesale (the round is not half-applied) and the loop
    exits with ``stall`` set so the host shell can delta-fetch the rows
    and re-enter at the same ``r`` — the miss costs one extra dispatch,
    never a wrong merge.

    ``dist_fn(nb [B, C]) -> (d, hit, valid)`` scores a flattened
    candidate batch, +inf on invalid lanes.
    """
    def cond(s):
        r, _ids, _d, _vis, curr, _ai, _ah, _it, stall = s
        return (r < r_stop) & ~stall & (curr >= 0).any()

    def body(s):
        r, ids, dists, visited, curr, acc_ids, acc_hit, iters, _ = s
        B, C = acc_ids.shape[0], acc_ids.shape[2]
        nb, res_ok = row_fn(curr)                     # [B, beam, R]
        stall = ((curr >= 0) & ~res_ok).any()
        nb = jnp.where(curr[..., None] >= 0, nb, -1).reshape(B, C)
        d, hit, valid = dist_fn(nb)
        active = (curr >= 0).any(1)                   # [B]
        ids2, d2, vis2 = merge_round(ids, dists, visited, nb, d, id_bound)
        curr2, vis2 = select_frontier(ids2, d2, vis2, beam)
        new = (r + 1, ids2, d2, vis2, curr2,
               acc_ids.at[:, r].set(jnp.where(valid, nb, -1)),
               acc_hit.at[:, r].set(hit & valid),
               iters + active.astype(jnp.int32))
        old = (r, ids, dists, visited, curr, acc_ids, acc_hit, iters)
        # a stalled round is discarded atomically: every carry leaf keeps
        # its pre-round value so the host re-enters at the same state
        return tuple(jnp.where(stall, o, n)
                     for o, n in zip(old, new)) + (stall,)

    return jax.lax.while_loop(cond, body, state)


# ---------------------------------------------------------------------------
# Device arm: in-memory tiers, one fused jitted program
# ---------------------------------------------------------------------------

def _device_distances(graph: GraphState, cache: CacheState, ids, queries):
    """Distances for an id batch [B, C] through the two device tiers: the
    ``l2_gather`` kernel arm against the capacity table, overlaid with the
    bandwidth-tier copy on cache hits. Invalid ids (< 0) come back +inf.
    Returns (dists [B, C] fp32, device_hit [B, C])."""
    cid = jnp.clip(ids, 0)
    slot = cache.h2d[cid]
    hit = (slot >= 0) & (ids >= 0)
    d_cap = gather_l2(graph.vectors, ids, queries)
    d_dev = gather_l2(cache.vectors, jnp.where(hit, slot, -1), queries)
    return jnp.where(hit, d_dev, d_cap), hit


def _frontier_search(graph: GraphState, cache: CacheState, queries, entries,
                     sp: SearchParams) -> SearchResult:
    """Hop-batched frontier executor, device arm (traceable; callers jit).
    queries [B, D], entries [B, L]. Rounds run through the shared
    ``_run_fused_rounds`` core — the capacity tier is device-resident, so
    ``row_fn`` never stalls and every round fuses into the one jitted
    while_loop, exactly the old bespoke loop's schedule (the parity suite
    pins this against the per-hop reference)."""
    B = queries.shape[0]
    L, R = sp.pool, graph.degree
    beam = max(1, min(sp.beam, L))
    rounds = _n_rounds(sp)
    C = beam * R
    id_bound = graph.capacity            # static: drives the packed dedup
    queries = queries.astype(graph.vectors.dtype)

    d0, _ = _device_distances(graph, cache, entries, queries)
    d0 = jnp.where(graph.alive[jnp.clip(entries, 0)] & (entries >= 0),
                   d0, INF)
    pool_ids0, pool_d0, visited0 = init_pool(entries, d0, id_bound)
    curr0, visited0 = select_frontier(pool_ids0, pool_d0, visited0, beam)

    def row_fn(curr):
        nb = graph.nbrs[jnp.clip(curr, 0)]            # always resident
        return nb, jnp.ones(curr.shape, bool)

    def dist_fn(nb):
        valid = (nb >= 0) & graph.alive[jnp.clip(nb, 0)]
        d, hit = _device_distances(graph, cache, nb, queries)
        return jnp.where(valid, d, INF), hit, valid

    state0 = (jnp.int32(0), pool_ids0, pool_d0, visited0, curr0,
              jnp.full((B, rounds, C), -1, jnp.int32),
              jnp.zeros((B, rounds, C), bool),
              jnp.zeros((B,), jnp.int32), jnp.bool_(False))
    (_, ids, dists, _, _, acc_ids, acc_hit, iters, _) = _run_fused_rounds(
        state0, rounds, beam, id_bound, row_fn, dist_fn)

    topk_ids = jnp.where(jnp.isfinite(dists[:, :sp.k]), ids[:, :sp.k], -1)
    return SearchResult(topk_ids, dists[:, :sp.k],
                        acc_ids.reshape(B, -1), acc_hit.reshape(B, -1),
                        iters)


@partial(jax.jit, static_argnames=("sp",))
def frontier_search(state: IndexState, queries, entries, sp: SearchParams
                    ) -> SearchResult:
    """Jitted executor entry with caller-chosen entry points (parity tests
    and update paths pass deterministic entries here)."""
    return _frontier_search(state.graph, state.cache,
                            queries.astype(jnp.float32), entries, sp)


@partial(jax.jit, static_argnames=("sp",))
def search_batch(state: IndexState, queries, key, sp: SearchParams
                 ) -> SearchResult:
    """Batched ANNS — thin entry point over the frontier executor.
    queries [B, D]. Entry points are random (paper §4.2: GPU-friendly, no
    seed maintenance under updates)."""
    B = queries.shape[0]
    n = jnp.maximum(state.graph.n, 1)
    entries = jax.random.randint(key, (B, sp.pool), 0, n, dtype=jnp.int32)
    return _frontier_search(state.graph, state.cache,
                            queries.astype(jnp.float32), entries, sp)


# ---------------------------------------------------------------------------
# Tiered arm: CPU traversal + disk IO, one device dispatch per round,
# speculative double-buffered staging between rounds
# ---------------------------------------------------------------------------

@jax.jit
def _batch_sqdist(x, q):
    """[B, C, D] gathered rows vs [B, D] queries -> [B, C] fp32 distances.
    Expansion form (‖x‖² − 2x·q + ‖q‖²): the inner product maps onto the
    batched-matmul path, ~1.4x the subtract-then-reduce einsum on CPU."""
    xq = jnp.matmul(x, q[:, :, None],
                    preferred_element_type=jnp.float32)[..., 0]
    x2 = jnp.einsum("bcd,bcd->bc", x, x,
                    preferred_element_type=jnp.float32)
    q2 = jnp.einsum("bd,bd->b", q, q,
                    preferred_element_type=jnp.float32)[:, None]
    return x2 - 2.0 * xq + q2


@partial(jax.jit, static_argnames=("beam", "id_bound"))
def _tiered_entry_dispatch(entry_ids, entry_vecs, entry_valid, queries,
                           beam, id_bound):
    """Entry-pool distances + dedup + sort + first frontier selection:
    the first of the per-round dispatches (shares the executor core with
    the device arm). Pool state stays device-resident across rounds; only
    the tiny [B, beam] frontier id matrix crosses back to the host."""
    d = _batch_sqdist(entry_vecs, queries)
    d = jnp.where(entry_valid, d, INF)
    pool_ids, pool_d, visited = init_pool(entry_ids, d, id_bound)
    curr, visited = select_frontier(pool_ids, pool_d, visited, beam)
    return pool_ids, pool_d, visited, curr


@partial(jax.jit, static_argnames=("beam", "id_bound"))
def _tiered_round_dispatch(pool_ids, pool_d, visited, cand_ids, uniq_vecs,
                           cand_inv, cand_valid, queries, beam, id_bound):
    """ONE jitted gather+distance+topk-merge(+next frontier selection)
    dispatch covering every hop in the round's beam — the tiered arm of
    the shared executor. The host ships each round's *unique* vectors
    ``uniq_vecs [U, D]`` (U padded to a power-of-two bucket to bound jit
    specializations) plus the lane->unique map ``cand_inv [B, C]``; the
    [B, C, D] candidate matrix is gathered here, so transfer volume
    scales with unique ids, not beam·degree lanes. Pool state never
    round-trips through the host."""
    xv = uniq_vecs[cand_inv]
    d = _batch_sqdist(xv, queries)
    d = jnp.where(cand_valid, d, INF)
    pool_ids, pool_d, visited = merge_round(pool_ids, pool_d, visited,
                                            cand_ids, d, id_bound)
    curr, visited = select_frontier(pool_ids, pool_d, visited, beam)
    return pool_ids, pool_d, visited, curr


# ---------------------------------------------------------------------------
# PQ code lane (quant.py): ADC dispatches over device-resident codes.
# Rounds never fetch vectors through the tier cascade — only adjacency
# rows cross tiers — and a final re-rank stage pulls exact vectors for
# the top pool entries through the cascade.
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("beam", "id_bound"))
def _pq_entry_dispatch(entry_ids, entry_valid, codes, centroids, queries,
                       beam, id_bound):
    """Entry-pool ADC scan + dedup + sort + first frontier selection —
    the code-lane twin of ``_tiered_entry_dispatch``. Builds the per-query
    ADC lookup tables in the same dispatch and returns them for reuse by
    every later round (the LUT is the only query-dependent PQ state)."""
    lut = adc_lut(centroids, queries)
    d = adc_gather(codes, lut, entry_ids)
    d = jnp.where(entry_valid, d, INF)
    pool_ids, pool_d, visited = init_pool(entry_ids, d, id_bound)
    curr, visited = select_frontier(pool_ids, pool_d, visited, beam)
    return pool_ids, pool_d, visited, curr, lut


@partial(jax.jit, static_argnames=("beam", "id_bound"))
def _pq_round_dispatch(pool_ids, pool_d, visited, cand_ids, cand_valid,
                       codes, lut, beam, id_bound):
    """ONE jitted code-gather + ADC + topk-merge (+ next frontier
    selection) dispatch covering every hop in the round's beam. Unlike
    the exact lane's ``_tiered_round_dispatch`` the host ships NOTHING
    per round — candidates are scored from the unconditionally resident
    codes, so per-round cross-tier traffic is adjacency rows only."""
    d = adc_gather(codes, lut, cand_ids)
    d = jnp.where(cand_valid, d, INF)
    pool_ids, pool_d, visited = merge_round(pool_ids, pool_d, visited,
                                            cand_ids, d, id_bound)
    curr, visited = select_frontier(pool_ids, pool_d, visited, beam)
    return pool_ids, pool_d, visited, curr


@partial(jax.jit, static_argnames=("beam", "id_bound"))
def _pq_fused_dispatch(pool_ids, pool_d, visited, curr, r, acc_ids,
                       topo_rows, topo_h2s, codes, lut, alive, r_stop,
                       beam, id_bound):
    """K consecutive PQ rounds in ONE jitted dispatch — the tiered arm's
    instantiation of the shared ``_run_fused_rounds`` core. While the
    frontier stays inside the device-resident topology cache the loop
    runs row gather (``kernels/row_gather`` over the cached adjacency
    table) -> ``pq_adc`` ADC scan -> topk merge -> next-frontier select
    entirely on device; a topology-cache miss stalls the loop atomically
    and returns the pre-round state, so the host shell delta-fetches the
    rows and re-enters at the same ``r``. ``r``/``r_stop`` are traced
    operands: re-entries and K-budget changes never recompile.

    Bit-parity with the per-round ``_pq_round_dispatch`` path holds by
    construction: the cached rows equal the store rows (epoch-fenced),
    the candidate mask/merge/select are the same shared core ops in the
    same order, and a stalled round is discarded wholesale."""
    def row_fn(c):
        nb = gather_rows(topo_rows, topo_h2s, c)       # [B, beam, R]
        slot = topo_h2s[jnp.clip(c, 0)]
        return nb, (slot >= 0) | (c < 0)               # idle lanes never stall

    def dist_fn(nb):
        valid = (nb >= 0) & alive[jnp.clip(nb, 0)]
        d = adc_gather(codes, lut, nb)
        # code-lane rounds log no per-round device hits: the PQ result's
        # hit flags are derived from exact-cache residency at the end
        return jnp.where(valid, d, INF), jnp.zeros(nb.shape, bool), valid

    B = pool_ids.shape[0]
    state0 = (r, pool_ids, pool_d, visited, curr, acc_ids,
              jnp.zeros(acc_ids.shape, bool), jnp.zeros((B,), jnp.int32),
              jnp.bool_(False))
    (r1, ids1, d1, vis1, curr1, acc1, _, _, _) = _run_fused_rounds(
        state0, r_stop, beam, id_bound, row_fn, dist_fn)
    return ids1, d1, vis1, curr1, r1, acc1


def _fused_topo_shell(store, topo, spec, alive, f_lam, pq, codes_j,
                      codes_epoch, lut, pool_ids, pool_d, visited, curr_j,
                      beam, rounds, id_bound, fused_rounds, stage_width=0,
                      alive_j=None):
    """Host fallback shell around ``_pq_fused_dispatch``: the executor's
    round loop when a topology cache is attached. Steady state is ONE
    fused dispatch covering every remaining round (dispatches/query drops
    to entry + fused + re-rank = 3); the host is re-entered only on a
    topology-cache miss (install the frontier's missing rows, re-enter at
    the same round) or the K-round budget (``fused_rounds``; 0 =
    uncapped). When the missing rows cannot be installed — the cache is
    too small or every slot is protected by the live frontier — ONE
    per-round ``_pq_round_dispatch`` runs with host-shipped ids (the
    forced-0%-hit-rate degenerate case runs entirely on this fallback and
    must stay bit-identical to the per-round executor, which it is: same
    dispatch, same inputs).

    ``_SpecPipeline`` integration re-targets speculation to *topology*
    one cache-miss ahead: while the fused dispatch is in flight the host
    ranks the frontier's non-resident next-hop candidates by F_λ and
    stages their store rows, so a future miss-exit's delta fetch is a
    memo hit instead of disk IO.

    Returns (pool_ids, pool_d, acc [B, rounds, C] np.int32, rounds
    executed, dispatches issued, topo hits, topo misses)."""
    B = int(pool_ids.shape[0])
    R = topo.degree
    C = beam * R
    K = fused_rounds if fused_rounds > 0 else rounds
    acc_j = jnp.full((B, rounds, C), -1, jnp.int32)
    acc_np = None
    fb_rounds: list = []
    dispatches = hits = misses = 0
    r = 0
    curr = np.asarray(curr_j)
    no_progress = 0
    while r < rounds and (curr >= 0).any():
        topo.validate(store)
        ep = store.write_epoch
        if ep != codes_epoch:       # concurrent insert: fold fresh codes
            codes_epoch = ep
            codes_j = pq.synced_codes()
        ucur = np.unique(curr[curr >= 0])
        cached_rows, resm = topo.lookup(ucur)
        need = ucur[~resm]
        hits += int(resm.sum())
        topo.hits += int(resm.sum())
        rows_need = None
        installed = True
        if need.size:
            misses += int(need.size)
            topo.misses += int(need.size)
            if spec is not None:
                spec.validate()
                rows_need = spec.rows_for(need)
            else:
                rows_need = store.fetch_rows(need, f_lam)
            # the live frontier is protected: an install can never evict
            # the rows the dispatch it feeds is about to gather
            installed = topo.install(need, rows_need, f_lam, protect=ucur)
        if installed and no_progress < 3:
            rows_j, h2s_j = topo.synced()
            out = _pq_fused_dispatch(
                pool_ids, pool_d, visited, curr_j,
                jnp.asarray(r, jnp.int32), acc_j, rows_j, h2s_j, codes_j,
                lut,
                # filtered search supplies the device-resident composite
                # mask (alive AND the predicate evaluated against the
                # attribute mirror); unfiltered ships the live bitset
                alive_j if alive_j is not None else jnp.asarray(alive),
                jnp.asarray(min(r + K, rounds), jnp.int32), beam, id_bound)
            dispatches += 1
            if spec is not None:
                # topology prefetch one cache-miss ahead, overlapping the
                # in-flight dispatch: stage store rows for the hottest
                # non-resident candidates reachable from this frontier
                if rows_need is not None:
                    cached_rows[~resm] = rows_need
                nxt = np.unique(cached_rows[cached_rows >= 0])
                nxt = nxt[topo.h2s[nxt] < 0]
                if nxt.size:
                    w = max(stage_width, 1) * B
                    if nxt.size > w:
                        nxt = nxt[np.argpartition(-f_lam[nxt], w - 1)[:w]]
                    spec.stage(nxt)
            pool_ids, pool_d, visited, curr_j, r_j, acc_j = out
            curr = np.asarray(curr_j)         # the shell's only sync point
            new_r = int(r_j)
            # a dispatch that advanced no round means residency changed
            # under us (concurrent install/evict): bounded retries, then
            # force the per-round fallback so the shell always progresses
            no_progress = no_progress + 1 if new_r == r else 0
            r = new_r
        else:
            if rows_need is not None:
                cached_rows[~resm] = rows_need
            nb = np.full((B, beam, R), -1, np.int32)
            okm = curr >= 0
            nb[okm] = cached_rows[np.searchsorted(ucur, curr[okm])]
            nb = nb.reshape(B, C)
            valid = (nb >= 0) & alive[np.clip(nb, 0, None)]
            pool_ids, pool_d, visited, curr_j = _pq_round_dispatch(
                pool_ids, pool_d, visited, jnp.asarray(nb),
                jnp.asarray(valid), codes_j, lut, beam, id_bound)
            dispatches += 1
            if acc_np is None:
                acc_np = np.full((B, rounds, C), -1, np.int32)
            acc_np[:, r] = np.where(valid, nb, -1)
            fb_rounds.append(r)
            curr = np.asarray(curr_j)
            r += 1
            no_progress = 0
    acc = np.array(acc_j)   # copy: jax buffers are read-only views
    if fb_rounds:   # overlay host-logged fallback rounds onto the device log
        acc[:, fb_rounds] = acc_np[:, fb_rounds]
    return pool_ids, pool_d, acc, r, dispatches, hits, misses


@partial(jax.jit, static_argnames=("depth",))
def _pq_filtered_scan_dispatch(codes, centroids, queries, cand_ids, depth):
    """Brute-force ADC scan over a filtered id set — the low-selectivity
    fallback's coarse stage: ONE ``pq_adc`` dispatch scoring every
    matching id (shipped as a -1-padded [B, Mp] matrix; the kernels map
    id -1 to +inf exactly as the graph lane's invalid-lane masking does)
    and keeping the top ``depth`` for the unchanged exact re-rank. No
    traversal: below the selectivity threshold a graph walk starves
    (too few passing candidates to sustain a frontier), while one flat
    scan over the matched set is small by definition."""
    lut = adc_lut(centroids, queries)
    d = adc_gather(codes, lut, cand_ids)
    d = jnp.where(cand_ids >= 0, d, INF)
    nd, idx = jax.lax.top_k(-d, depth)
    ids = jnp.take_along_axis(cand_ids, idx, axis=1)
    return jnp.where(jnp.isfinite(-nd), ids, -1), -nd


@partial(jax.jit, static_argnames=("k",))
def _pq_rerank_dispatch(top_ids, uniq_vecs, cand_inv, valid, queries, k):
    """Tier-cascade exact re-rank: the top ``depth`` ADC-ranked pool
    entries, their exact vectors fetched through the cascade by the host
    (shipped as unique rows + lane->unique map, like the exact round
    dispatch), re-scored with the same ``_batch_sqdist`` the exact lane
    uses and re-sorted. At ``depth == pool`` this makes the PQ lane's
    output identical to the exact executor's whenever the traversal
    visited the same pool (pinned by the parity suite with a lossless
    codebook)."""
    xv = uniq_vecs[cand_inv]                       # [B, depth, D]
    d = _batch_sqdist(xv, queries)
    d = jnp.where(valid, d, INF)
    nd, order = jax.lax.top_k(-d, d.shape[1])
    ids = jnp.take_along_axis(top_ids, order, axis=1)
    ds = -nd
    ids = jnp.where(jnp.isfinite(ds), ids, -1)
    return ids[:, :k], ds[:, :k]


def dedup_mask(a):
    """Per-row duplicate flags for an int array [B, C] (any one occurrence
    survives). Host twin of ``dup_mask_jnp``; shared by the tiered update
    paths."""
    order = np.argsort(a, axis=1, kind="stable")
    srt = np.take_along_axis(a, order, axis=1)
    dup_sorted = np.concatenate(
        [np.zeros((a.shape[0], 1), bool), srt[:, 1:] == srt[:, :-1]], axis=1)
    dup = np.empty_like(dup_sorted)
    np.put_along_axis(dup, order, dup_sorted, axis=1)
    return dup


class TieredSearchResult(NamedTuple):
    ids: np.ndarray       # [B, k]
    dists: np.ndarray     # [B, k]
    acc_ids: np.ndarray   # [B, rounds*beam*R] accessed vertex ids (-1 pad)
    acc_hit: np.ndarray   # [B, rounds*beam*R] device-cache-hit flags
    iters: int            # expansion rounds executed
    dispatches: int       # jitted device dispatches issued (per-round:
    #                       1 + iters + rerank; fused: entry + fused
    #                       re-entries + fallback rounds + rerank)
    spec_hits: int = 0    # frontier rows already staged at read-back
    spec_misses: int = 0  # frontier rows delta-fetched after read-back
    topo_hits: int = 0    # frontier ids resident in the topology cache
    topo_misses: int = 0  # frontier ids delta-fetched + installed
    filter_path: str = "none"        # "none" | "graph" | "fallback"
    filter_selectivity: float = 1.0  # admission-time sampled estimate

    @property
    def spec_hit_rate(self) -> float:
        t = self.spec_hits + self.spec_misses
        return self.spec_hits / t if t else 0.0

    @property
    def topo_hit_rate(self) -> float:
        t = self.topo_hits + self.topo_misses
        return self.topo_hits / t if t else 0.0


def _resolve_unique_vectors(ids, h2d, cache_vec, store, f_lam):
    """Vectors for a batch of *unique* non-negative ids through the
    cascade device cache (mirror) -> host window -> disk. Returns
    (vectors [U, D] fp32, device_hit [U])."""
    out = np.empty((len(ids), store.disk.dim), np.float32)
    slot = h2d[ids]
    hit = slot >= 0
    if hit.any():
        out[hit] = cache_vec[slot[hit]]
    miss = ~hit
    if miss.any():
        out[miss] = store.fetch(ids[miss], f_lam)[0]
    return out, hit


def _host_sqdist(x, q):
    """Numpy twin of ``_batch_sqdist`` for host-side frontier prediction:
    [B, C, D] vs [B, D] -> [B, C]."""
    diff = x - q[:, None, :]
    return np.einsum("bcd,bcd->bc", diff, diff)


def predict_frontier(ids, valid, f_lam, width, d_host=None):
    """Ranked next-frontier guess [B, width] (-1 = no guess) — the F_λ
    probe of the old prefetch predictor extended to return the guess
    itself: per query, the top-``width`` valid candidates by host-side
    score. The entry stage passes exact host distances (``d_host``, the
    entry vectors are host-resident anyway) and predicts the first
    frontier almost perfectly; later rounds rank by the WAVP F_λ
    predictor — hot hub candidates are the likeliest next expansions."""
    score = (-d_host if d_host is not None
             else f_lam[np.clip(ids, 0, None)])
    score = np.where(valid, score, -np.inf)
    w = min(width, ids.shape[1])
    part = np.argpartition(-score, w - 1, axis=1)[:, :w]
    got = np.take_along_axis(ids, part, axis=1)
    ok = np.isfinite(np.take_along_axis(score, part, axis=1))
    return np.where(ok, got, -1)


class _StageMap:
    """Append-only id -> payload staging memo (speculative buffers).

    Dense ``loc`` directory for O(1) vectorized lookup, doubling buffer
    for amortized O(1) installs, and O(installed) wholesale invalidation:
    the write-epoch check flushes the memo outright rather than patching
    it — speculation must never serve a stale row."""

    __slots__ = ("loc", "buf", "hit", "n", "_installed")

    def __init__(self, capacity: int, width: int, dtype, track_hit=False):
        self.loc = np.full((capacity,), -1, np.int64)
        self.buf = np.empty((0, width), dtype)
        self.hit = np.empty((0,), bool) if track_hit else None
        self.n = 0
        self._installed: list = []

    def add(self, ids, rows, hit=None):
        m = len(ids)
        if not m:
            return
        need = self.n + m
        if need > len(self.buf):
            cap = max(need, 2 * len(self.buf), 256)
            buf = np.empty((cap, self.buf.shape[1]), self.buf.dtype)
            buf[:self.n] = self.buf[:self.n]
            self.buf = buf
            if self.hit is not None:
                h = np.empty((cap,), bool)
                h[:self.n] = self.hit[:self.n]
                self.hit = h
        self.buf[self.n:need] = rows
        if self.hit is not None:
            self.hit[self.n:need] = hit
        self.loc[ids] = np.arange(self.n, need)
        self._installed.append(np.asarray(ids))
        self.n = need

    def clear(self):
        for blk in self._installed:
            self.loc[blk] = -1
        self._installed.clear()
        self.n = 0


class _SpecPipeline:
    """Speculative double-buffered stage for the tiered arm (§4.4).

    While round N's dispatch is in flight the host stages the predicted
    round-N+1 frontier: adjacency rows for the predicted ids, vectors for
    their neighborhoods, and an async disk prefetch one hop further. At
    read-back, staged frontier ids feed the next dispatch immediately;
    mispredictions cost a delta fetch of the missing rows only. Both
    memos are validated against the store's write epoch every round — a
    concurrent insert/delete flushes them wholesale, so speculation reads
    are never staler than the non-speculative path's per-round fetches
    (MVCC consistency is the store's, unchanged)."""

    def __init__(self, backend, h2d, cache_vec, f_lam, *,
                 prefetch_budget=0, probe=8, stage_vectors=True):
        self.store = backend.store
        self.h2d, self.cache_vec, self.f_lam = h2d, cache_vec, f_lam
        self.prefetch_budget = prefetch_budget
        self.probe = probe
        self.stage_vectors = stage_vectors   # False: PQ code lane — rounds
        #                                      never need vectors, stage
        #                                      rows (+ disk prefetch) only
        cap = backend.capacity
        self.rows = _StageMap(cap, backend.degree, np.int32)
        self.vecs = _StageMap(cap, backend.dim, np.float32, track_hit=True)
        self.epoch = self.store.write_epoch
        self.hits = 0
        self.misses = 0

    def validate(self):
        ep = self.store.write_epoch
        if ep != self.epoch:
            self.rows.clear()
            self.vecs.clear()
            self.epoch = ep

    def rows_for(self, uids, *, speculative=False):
        """Adjacency rows aligned with ``uids`` (unique, >= 0): staged ids
        come from the memo, the rest are delta-fetched and installed.
        Demand reads (``speculative=False``) score the hit-rate."""
        loc = self.rows.loc[uids]
        miss = loc < 0
        if not speculative:
            self.hits += int((~miss).sum())
            self.misses += int(miss.sum())
        if miss.any():
            mids = uids[miss]
            self.rows.add(mids, self.store.fetch_rows(mids, self.f_lam))
            loc = self.rows.loc[uids]
        return self.rows.buf[loc]

    def vectors_for(self, uids):
        """(vectors [U, D], device_hit [U]) aligned with unique ids."""
        loc = self.vecs.loc[uids]
        miss = loc < 0
        if miss.any():
            mids = uids[miss]
            v, h = _resolve_unique_vectors(mids, self.h2d, self.cache_vec,
                                           self.store, self.f_lam)
            self.vecs.add(mids, v, h)
            loc = self.vecs.loc[uids]
        return self.vecs.buf[loc], self.vecs.hit[loc]

    def stage(self, pred):
        """Speculative stage — runs while the dispatch is in flight."""
        ids = np.unique(pred[pred >= 0])
        if not ids.size:
            return
        self.validate()
        rows = self.rows_for(ids, speculative=True)
        nxt = np.unique(rows[rows >= 0])
        if not nxt.size:
            return
        if self.stage_vectors:
            self.vectors_for(nxt)
        if self.prefetch_budget > 0:
            self._prefetch_two_ahead(nxt)

    def _prefetch_two_ahead(self, cand):
        """Async disk prefetch one hop past the staged frontier (the old
        predicted-prefetch, now fed by the speculative stage): peek the
        hottest staged candidates' adjacency and enqueue their cold
        neighbors, overlapping the round after next as well."""
        if cand.size > self.probe:
            cand = cand[np.argpartition(-self.f_lam[cand],
                                        self.probe - 1)[:self.probe]]
        hrows = self.store.peek_rows(cand)
        nxt = np.unique(hrows[hrows >= 0])
        nxt = nxt[self.store.loc[nxt] < 0]
        if nxt.size:
            b = self.prefetch_budget
            if nxt.size > b:
                nxt = nxt[np.argpartition(-self.f_lam[nxt], b - 1)[:b]]
            self.store.prefetch(nxt, self.f_lam)


def _predict_prefetch(store, nb, valid, f_lam, budget, probe=8):
    """Predicted next-frontier prefetch for the NON-speculative path
    (paper §4.4 multi-stream overlap): peek the hottest candidates'
    adjacency while the dispatch is in flight, enqueue their non-resident
    neighbors to the background prefetcher."""
    cand = np.unique(nb[valid])
    if not cand.size:
        return
    if cand.size > probe:     # argpartition: this runs once per round
        cand = cand[np.argpartition(-f_lam[cand], probe - 1)[:probe]]
    hrows = store.peek_rows(cand)
    nxt = np.unique(hrows[hrows >= 0])
    nxt = nxt[store.loc[nxt] < 0]
    if nxt.size:
        if nxt.size > budget:
            nxt = nxt[np.argpartition(-f_lam[nxt], budget - 1)[:budget]]
        store.prefetch(nxt, f_lam)


def _ship_unique_vectors(ids, valid, resolve, pad_to=None):
    """The executor's ship-unique protocol, shared by the exact round
    dispatch and the PQ re-rank stage: dedup a [B, C] id matrix (invalid
    lanes collapse onto placeholder id 0 — their distances are masked in
    the dispatch), resolve vectors for the unique ids through
    ``resolve`` (cascade or speculative memo), and zero-pad the device
    transfer — to the pow4 bucket by default (O(log) compile
    specializations), or to the STATIC ``pad_to`` (>= B·C suffices,
    unique counts cannot exceed the lane count). The re-rank stage uses
    the static pad: it runs once per query batch and its unique count
    rides the 512/2048 bucket boundary as the dataset streams, which
    used to drop a fresh XLA compile into the serving path right after
    inserts. Returns (uvec [U, D], uhit [len(uc)], inv [B, C] int32)."""
    B, C = ids.shape
    uc, inv = np.unique(np.where(valid, ids, 0).reshape(-1),
                        return_inverse=True)
    uvec, uhit = resolve(uc)
    U = pad_to if pad_to is not None else _pow2_bucket(len(uc))
    if U != len(uc):
        uvec = np.concatenate(
            [uvec, np.zeros((U - len(uc), uvec.shape[1]), np.float32)])
    return uvec, uhit, inv.reshape(B, C).astype(np.int32)


def _pow2_bucket(u: int, floor: int = 512) -> int:
    """Pad unique-row counts to power-of-FOUR buckets (512 floor) so the
    round dispatch compiles a handful of specializations, not one per
    count — and, as important, so steady-state serving rarely straddles a
    bucket boundary (a mid-run boundary crossing is a fresh XLA compile
    on the hot path, which is exactly the tail-latency spike the
    percentile satellite hunts). Padded rows are zeros the lane->unique
    gather never references; their transfer cost is noise."""
    b = floor
    while b < u:
        b *= 4
    return b


def effective_rerank_depth(rerank_depth: int, k: int, pool: int) -> int:
    """Resolve the ``rerank_depth`` knob to the concrete pool prefix the
    exact re-rank stage pulls vectors for: ``<= 0`` is the whole-pool
    sentinel, anything else clamps to ``[k, pool]``. The SLO degradation
    ladder (core/slo.py) halves through this same resolution so a
    degraded depth and the executor agree on sentinel semantics."""
    return pool if rerank_depth <= 0 else max(k, min(rerank_depth, pool))


def _filtered_brute_force(backend, queries, qj, hmask, alive_snap, sp,
                          pq, rerank_depth, h2d, cache_vec, f_lam,
                          filter_sel) -> TieredSearchResult:
    """Selectivity-adaptive fallback arm of ``search_tiered``: exact
    search restricted to the matched id set, no graph traversal. PQ
    mode: ONE ``pq_adc`` scan over the matched ids keeps the top
    ``rerank_depth``, then the executor's unchanged exact re-rank
    dispatch; exact mode: the re-rank dispatch alone over every match.
    Results are exact over the matched set by construction (modulo PQ
    pre-ranking when ``rerank_depth`` < matches), so this path's output
    at full depth is bit-identical to post-filtering an exhaustive
    scan — the property the filter suite pins."""
    B = queries.shape[0]
    k = sp.k
    n = max(backend.n, 1)
    matched = np.where(alive_snap[:n] & hmask[:n])[0]
    if matched.size == 0:
        z = np.zeros((B, 0), np.int32)
        return TieredSearchResult(
            np.full((B, k), -1, np.int32),
            np.full((B, k), np.inf, np.float32),
            z, z.astype(bool), 0, 0,
            filter_path="fallback", filter_selectivity=filter_sel)
    Mp = _pow2_bucket(matched.size)
    cand = np.full((Mp,), -1, np.int64)
    cand[:matched.size] = matched
    cand_ids = np.broadcast_to(cand, (B, Mp))
    dispatches = 0
    if pq is not None:
        codes_j = pq.synced_codes()
        depth = min(effective_rerank_depth(rerank_depth, k, sp.pool), Mp)
        top_j, _ = _pq_filtered_scan_dispatch(
            codes_j, pq.codebook.centroids, qj,
            jnp.asarray(cand_ids, jnp.int32), depth)
        dispatches += 1
        top_ids = np.asarray(top_j, np.int64)
    else:
        top_ids = cand_ids
    valid_r = top_ids >= 0
    uvec, _, inv = _ship_unique_vectors(
        top_ids, valid_r,
        lambda u: _resolve_unique_vectors(u, h2d, cache_vec, backend.store,
                                          f_lam))
    ids_k, d_k = _pq_rerank_dispatch(
        jnp.asarray(top_ids, jnp.int32), jnp.asarray(uvec),
        jnp.asarray(inv), jnp.asarray(valid_r), qj, k)
    dispatches += 1
    ids_np = np.asarray(ids_k, np.int32)
    d_np = np.asarray(d_k, np.float32)
    if ids_np.shape[1] < k:      # fewer matches than k: pad the tail
        pad = k - ids_np.shape[1]
        ids_np = np.pad(ids_np, ((0, 0), (0, pad)), constant_values=-1)
        d_np = np.pad(d_np, ((0, 0), (0, pad)), constant_values=np.inf)
    acc = np.where(valid_r, top_ids, -1).astype(np.int32)
    acc_hit = (h2d[np.clip(acc, 0, None)] >= 0) & (acc >= 0)
    return TieredSearchResult(ids_np, d_np, acc, acc_hit, 0, dispatches,
                              filter_path="fallback",
                              filter_selectivity=filter_sel)


def search_tiered(backend, cache_mirror, queries, seed, sp: SearchParams,
                  *, f_lam=None, prefetch_budget: int = 0,
                  entry_ids=None, speculate: bool = True,
                  spec_width: int = 0, spec_rank: str = "flam",
                  spec_predict=None, pq=None,
                  rerank_depth: int = 0, topo=None,
                  fused_rounds: int = 0, filter=None,
                  filter_fallback_selectivity: float = 0.0,
                  filter_sample: int = 1024) -> TieredSearchResult:
    """Hop-batched frontier search over a disk-backed graph (paper
    Algorithm 1 in its GPU-CPU-disk form) — the tiered arm of the shared
    executor, run as a two-stage speculative pipeline. Per round: ONE
    bulk (delta) row fetch, ONE unique-id vector cascade, ONE jitted
    distance+merge dispatch; while that dispatch is in flight the host
    predicts the next frontier and stages its rows/vectors
    (``_SpecPipeline``), so at the read-back sync only mispredicted ids
    still need IO. Speculation is bitwise-transparent: staged payloads
    are the same values the demand path would fetch (the write-epoch
    check flushes the memo on any concurrent mutation), so results are
    identical to ``speculate=False`` — the property suite enforces this
    under forced 0% and 100% misprediction.

    backend: ``tiers.TieredBackend``; cache_mirror: ``cache.HostPlacement``
    (readers snapshot its arrays once, see HostPlacement docs).
    ``entry_ids`` [B, pool] overrides the random entry pool (parity tests).
    ``spec_width``: predicted frontier ids staged per query per round
    (0 -> beam). ``spec_rank``: ``"flam"`` (default) ranks round
    predictions with the F_λ probe alone; ``"dist"`` re-ranks by exact
    host distances over the staged unique vectors — higher hit-rate but
    ~2ms/round of host compute, worth it only when delta fetches are
    genuinely IO-bound (disk much slower than this pod's page cache). ``spec_predict``: prediction
    hook with the signature of ``predict_frontier`` (tests force 0%/100%
    misprediction through it).

    ``pq``: a ``quant.PQCodes`` lane — when set, the executor runs in
    coarse-then-refine mode: every round scores candidates on device from
    the unconditionally resident PQ codes (ADC LUT gather; NO per-round
    vector cascade fetch — only adjacency rows cross tiers, and the
    speculative pipeline stages rows only), then a final re-rank stage
    pulls exact vectors for the top ``rerank_depth`` pool entries through
    the existing cascade (device cache -> host window -> disk) and
    re-scores them exactly. ``rerank_depth`` <= 0 re-ranks the whole
    pool; it is clamped to [k, pool]. At ``rerank_depth == pool`` with a
    lossless codebook the PQ lane reproduces the exact executor's
    results (parity suite). ``spec_rank="dist"`` degrades to the F_λ
    probe in PQ mode: the stage holds no host vectors to re-rank with.

    ``topo``: a ``cache.TopoCache`` device-resident topology lane — when
    set (PQ mode only; the exact lane needs host vectors every round
    regardless), the round loop runs through the K-round fused dispatch
    (``_pq_fused_dispatch`` + ``_fused_topo_shell``): while the frontier
    stays inside the cached topology, row gather -> ADC scan -> merge ->
    select all happen on device in one ``lax.while_loop`` dispatch, and
    the host is re-entered only on a topology-cache miss or the
    ``fused_rounds`` budget (0 = uncapped). Results are bit-identical to
    the per-round executor (parity suite pins K ∈ {1, 2, 4} and forced
    0%/100% topology hit rates).

    ``filter``: a ``filters.FilterSpec`` metadata predicate — requires an
    attached ``backend.attrs`` store. Selectivity is sampled at admission
    (``filter_sample`` ids, deterministic in ``seed``): at or above
    ``filter_fallback_selectivity`` the predicate joins the executor's
    invalid-lane masking (filtered-out candidates never enter the pool,
    both arms); below it the query routes to the brute-force scan over
    the matched set (``_filtered_brute_force``). The chosen path and the
    measured selectivity ride the result (``filter_path`` /
    ``filter_selectivity``).
    """
    store = backend.store
    alive = backend.alive
    # ONE snapshot read: h2d and vectors must come from the same publish
    # (see cache.CacheView) or a concurrent placement pass could pair an
    # old mapping with new payloads
    view = cache_mirror.view
    h2d, cache_vec = view.h2d, view.vectors
    if f_lam is None:   # callers doing several passes precompute O(N) once
        f_lam = cache_mirror.scores(backend.e_in)

    queries = np.asarray(queries, np.float32)
    B, D = queries.shape
    L, R, k = sp.pool, backend.degree, sp.k
    beam = max(1, min(sp.beam, L))
    rounds = _n_rounds(sp)
    C = beam * R
    n = max(backend.n, 1)
    id_bound = int(backend.capacity)
    qj = jnp.asarray(queries)

    # --- predicate lane (core/filters.py) -------------------------------
    filter_path, filter_sel = "none", 1.0
    alive_j = None
    if filter is not None:
        from repro.core.filters import (compile_filter, device_pass_mask,
                                        estimate_selectivity, host_pass)
        attrs = backend.attrs
        if attrs is None:
            raise ValueError("filtered search requires an attached "
                             "attribute store (EngineConfig.attributes)")
        cf = compile_filter(filter, attrs.schema)
        hmask = host_pass(cf, attrs.tags, attrs.nums)
        filter_sel = estimate_selectivity(cf, attrs, alive, backend.n,
                                          sample=filter_sample, seed=seed)
        if filter_sel < filter_fallback_selectivity:
            # graph walk would starve: brute-force scan the matched set
            return _filtered_brute_force(backend, queries, qj, hmask,
                                         alive, sp, pq, rerank_depth,
                                         h2d, cache_vec, f_lam, filter_sel)
        filter_path = "graph"
        # composite alive: the predicate folds into the executor's
        # existing -1/alive invalid-lane masking everywhere (entry pool,
        # per-round valid, kernels' id -1 -> +inf), so filtered-out
        # candidates never enter the pool. The host copy is a consistent
        # cut of the bitset; the device twin below is ANDed from the
        # epoch-synced attribute mirror for the fused in-cache rounds.
        alive = alive & hmask                         # np copy, not a view
        if pq is not None and topo is not None:
            alive_j = jnp.asarray(backend.alive) & device_pass_mask(attrs,
                                                                    cf)
    if entry_ids is None:
        rng = np.random.default_rng(seed)
        entry_ids = rng.integers(0, n, (B, L))
    entry_ids = np.asarray(entry_ids, np.int64)

    use_pq = pq is not None
    if use_pq:
        # epoch read BEFORE the sync: a write racing the sync re-syncs
        # next round rather than never. The hazard is real — alive is
        # read live per round, so an id inserted mid-search can enter a
        # round via a reverse-edge-updated row and would otherwise be
        # scored from its still-zero code row.
        codes_epoch = store.write_epoch
        codes_j = pq.synced_codes()
        depth = effective_rerank_depth(rerank_depth, k, L)

    spec = None
    if speculate:
        spec = _SpecPipeline(backend, h2d, cache_vec, f_lam,
                             prefetch_budget=prefetch_budget,
                             stage_vectors=not use_pq)
        spec.validate()
        width = spec_width if spec_width > 0 else beam
        predict = spec_predict if spec_predict is not None else \
            predict_frontier

    entry_alive = alive[entry_ids]
    if use_pq:
        # entry pool scored from device-resident codes: no vector fetch
        # at all (the lane's LUTs are built inside the same dispatch)
        pool_ids, pool_d, visited, curr_j, lut = _pq_entry_dispatch(
            jnp.asarray(entry_ids, jnp.int32), jnp.asarray(entry_alive),
            codes_j, pq.codebook.centroids, qj, beam, id_bound)
        dispatches = 1
        if spec is not None:
            # no host vectors in the code lane: the entry prediction
            # falls back to the F_λ probe (rows-only staging)
            spec.stage(predict(entry_ids, entry_alive, f_lam, width))
    else:
        # entry pool: one unique-id cascade + one entry dispatch
        ue, inv_e = np.unique(entry_ids.reshape(-1), return_inverse=True)
        if spec is not None:
            uev, _ = spec.vectors_for(ue)
        else:
            uev, _ = _resolve_unique_vectors(ue, h2d, cache_vec, store,
                                             f_lam)
        ev = uev[inv_e].reshape(B, L, D)
        pool_ids, pool_d, visited, curr_j = _tiered_entry_dispatch(
            jnp.asarray(entry_ids, jnp.int32), jnp.asarray(ev),
            jnp.asarray(entry_alive), qj, beam, id_bound)
        dispatches = 1
        if spec is not None:
            # stage round 1 while the entry dispatch is in flight: the
            # entry vectors are host-resident, so the first frontier is
            # predicted from exact host distances
            pred = predict(entry_ids, entry_alive, f_lam, width,
                           d_host=_host_sqdist(ev, queries))
            spec.stage(pred)
    curr = np.asarray(curr_j)                 # [B, beam], -1 = idle lane

    acc_ids = np.full((B, rounds, C), -1, np.int32)
    acc_hit = np.zeros((B, rounds, C), bool)
    it = 0
    topo_hits = topo_misses = 0
    if use_pq and topo is not None:
        # fused multi-round executor: the shell owns the round loop and
        # issues ONE lax.while_loop dispatch per contiguous in-cache run
        (pool_ids, pool_d, acc_ids, it, extra, topo_hits,
         topo_misses) = _fused_topo_shell(
            store, topo, spec, alive, f_lam, pq, codes_j, codes_epoch,
            lut, pool_ids, pool_d, visited, curr_j, beam, rounds,
            id_bound, fused_rounds,
            stage_width=(width if spec is not None else 0),
            alive_j=alive_j)
        dispatches += extra
    else:
        for _ in range(rounds):
            ok = curr >= 0
            if not ok.any():
                break
            # ONE bulk row fetch for the whole beam (topology lives on
            # host/disk only; the device cache stores vectors). Staged rows
            # from the speculative stage short-circuit it to a delta fetch.
            ucur = np.unique(curr[ok])
            if spec is not None:
                spec.validate()
                urows = spec.rows_for(ucur)
            else:
                urows = store.fetch_rows(ucur, f_lam)
            nb = np.full((B, beam, R), -1, np.int32)
            # searchsorted over the (sorted) unique ids: O(|curr| log |ucur|),
            # no O(dataset) scratch on the per-round hot path
            nb[ok] = urows[np.searchsorted(ucur, curr[ok])]
            nb = nb.reshape(B, C)

            valid = (nb >= 0) & alive[np.clip(nb, 0, None)]
            if use_pq:
                ep = store.write_epoch
                if ep != codes_epoch:   # concurrent insert: fold fresh codes
                    codes_epoch = ep
                    codes_j = pq.synced_codes()
                # code-lane round: candidates scored from device-resident
                # codes — nothing but the id matrix crosses to the device
                pool_ids, pool_d, visited, curr_j = _pq_round_dispatch(
                    pool_ids, pool_d, visited, jnp.asarray(nb),
                    jnp.asarray(valid), codes_j, lut, beam, id_bound)
                dispatches += 1
                acc_ids[:, it] = np.where(valid, nb, -1)
                if spec is not None:
                    if it + 1 < rounds:
                        spec.stage(predict(nb, valid, f_lam, width))
                elif prefetch_budget > 0:
                    _predict_prefetch(store, nb, valid, f_lam, prefetch_budget)
                curr = np.asarray(curr_j)         # the round's only sync point
                it += 1
                continue
            uvec, uhit, inv = _ship_unique_vectors(
                nb, valid,
                spec.vectors_for if spec is not None else
                (lambda u: _resolve_unique_vectors(u, h2d, cache_vec, store,
                                                   f_lam)))
            # launch the round's single device dispatch (async); pool state
            # stays device-resident, only `curr` crosses back. The speculative
            # stage below overlaps with the in-flight dispatch.
            pool_ids, pool_d, visited, curr_j = _tiered_round_dispatch(
                pool_ids, pool_d, visited, jnp.asarray(nb), jnp.asarray(uvec),
                jnp.asarray(inv), jnp.asarray(valid), qj, beam, id_bound)
            dispatches += 1
            acc_ids[:, it] = np.where(valid, nb, -1)
            acc_hit[:, it] = uhit[inv] & valid
            if spec is not None:
                if it + 1 < rounds:   # the last round has no next to stage for
                    d_host = None
                    if spec_rank == "dist":
                        # re-rank by exact host distances (the unique vectors
                        # are already host-resident): sharper than the F_λ
                        # probe, and the cost hides under the in-flight
                        # dispatch like the rest of the stage
                        d_host = _host_sqdist(uvec[inv], queries)
                    spec.stage(predict(nb, valid, f_lam, width, d_host=d_host))
            elif prefetch_budget > 0:
                _predict_prefetch(store, nb, valid, f_lam, prefetch_budget)
            curr = np.asarray(curr_j)             # the round's only sync point
            it += 1

    if use_pq:
        # device-hit flags for the placement pass: in the code lane an
        # access "hits" when its id sits in the exact-vector device cache
        # (the tier the re-rank stage reads), so WAVP keeps promoting the
        # hot re-rank set while codes stay unconditionally resident
        flat = acc_ids.reshape(B, -1)
        acc_hit_flat = (h2d[np.clip(flat, 0, None)] >= 0) & (flat >= 0)

        # tier-cascade exact re-rank of the top ADC-ranked pool entries
        pool_ids_np, pool_d_np = np.asarray(pool_ids), np.asarray(pool_d)
        top_ids = pool_ids_np[:, :depth]
        valid_r = (top_ids >= 0) & np.isfinite(pool_d_np[:, :depth])
        uvec, _, inv = _ship_unique_vectors(
            top_ids, valid_r,
            lambda u: _resolve_unique_vectors(u, h2d, cache_vec, store,
                                              f_lam),
            pad_to=top_ids.size)
        ids_k, d_k = _pq_rerank_dispatch(
            jnp.asarray(top_ids, jnp.int32), jnp.asarray(uvec),
            jnp.asarray(inv), jnp.asarray(valid_r), qj, k)
        dispatches += 1
        return TieredSearchResult(
            np.asarray(ids_k, np.int32), np.asarray(d_k),
            flat, acc_hit_flat, it, dispatches,
            spec.hits if spec else 0, spec.misses if spec else 0,
            topo_hits, topo_misses, filter_path, filter_sel)

    pool_ids, pool_d = np.asarray(pool_ids), np.asarray(pool_d)
    topk_ids = np.where(np.isfinite(pool_d[:, :k]), pool_ids[:, :k], -1)
    return TieredSearchResult(topk_ids.astype(np.int32), pool_d[:, :k],
                              acc_ids.reshape(B, -1),
                              acc_hit.reshape(B, -1), it, dispatches,
                              spec.hits if spec else 0,
                              spec.misses if spec else 0,
                              filter_path=filter_path,
                              filter_selectivity=filter_sel)


def brute_force_topk(graph: GraphState, queries, k):
    """Exact ground truth over alive vectors (recall oracle)."""
    d = (jnp.sum(queries ** 2, 1, keepdims=True)
         - 2.0 * queries @ graph.vectors.T
         + jnp.sum(graph.vectors ** 2, 1)[None, :])
    d = jnp.where(graph.alive[None, :], d, INF)
    nd, idx = jax.lax.top_k(-d, k)
    return idx, -nd


def recall_at_k(found_ids, true_ids):
    """found/true [B, k] -> mean fraction of true ids found."""
    hits = (found_ids[:, :, None] == true_ids[:, None, :]).any(1)
    return jnp.mean(hits.astype(jnp.float32))
