"""ANNS with CPU-GPU co-processing (paper Algorithm 1), TPU adaptation.

Batched greedy beam search: one vmap lane per query (the paper's
one-thread-block-per-query), neighbor expansion restructured as batched
gather + distance GEMV on the MXU. Each expansion consults the cache
mapping table; hits read the bandwidth-tier copy, misses read the capacity
tier and are logged so the post-batch WAVP pass (cache.py) can decide
promote-vs-compute-in-place with batch-amortized transfer cost (the paper
amortizes T_transfer over batches of 2048).

Returns per-query top-k plus the access/hit logs consumed by
``repro.core.cache.apply_wavp``.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.types import CacheState, GraphState, IndexState, SearchParams

INF = jnp.float32(jnp.inf)


class SearchResult(NamedTuple):
    ids: jax.Array        # [B, k]
    dists: jax.Array      # [B, k]
    acc_ids: jax.Array    # [B, I*R] accessed vertex ids (-1 pad)
    acc_hit: jax.Array    # [B, I*R] cache-hit flags
    iters: jax.Array      # [B] iterations used


def _gather_tiered(graph: GraphState, cache: CacheState, ids):
    """Fetch vectors for ids through the tier hierarchy: cached rows come
    from the bandwidth tier, the rest from the capacity tier."""
    slot = cache.h2d[jnp.clip(ids, 0)]
    hit = (slot >= 0) & (ids >= 0)
    dev = cache.vectors[jnp.clip(slot, 0)]
    host = graph.vectors[jnp.clip(ids, 0)]
    # NB: no astype here — converting gathered rows makes XLA hoist a full
    # fp32 copy of the table; distances accumulate in fp32 via einsum
    return jnp.where(hit[:, None], dev, host), hit


def _sqdist(x, q):
    """Squared L2 with fp32 accumulation over (possibly bf16) operands."""
    diff = x - q
    return jnp.einsum("kd,kd->k", diff, diff,
                      preferred_element_type=jnp.float32)


def _search_one(graph: GraphState, cache: CacheState, q, entry_ids,
                sp: SearchParams):
    L = sp.pool
    R = graph.degree
    I = sp.max_iters
    q = q.astype(graph.vectors.dtype)

    ev, _ = _gather_tiered(graph, cache, entry_ids)
    d0 = _sqdist(ev, q)
    d0 = jnp.where(graph.alive[entry_ids], d0, INF)
    # dedup entry ids
    dup = jnp.triu(entry_ids[:, None] == entry_ids[None, :], k=1).any(0)
    d0 = jnp.where(dup, INF, d0)
    order = jnp.argsort(d0)
    ids0, dist0 = entry_ids[order], d0[order]
    visited0 = jnp.zeros((L,), bool)

    acc_ids0 = jnp.full((I, R), -1, jnp.int32)
    acc_hit0 = jnp.zeros((I, R), bool)

    def cond(s):
        it, ids, dists, visited, *_ = s
        frontier = (~visited) & jnp.isfinite(dists)
        return (it < I) & frontier.any()

    def body(s):
        it, ids, dists, visited, acc_ids, acc_hit = s
        sel = jnp.where(visited | ~jnp.isfinite(dists), INF, dists)
        best = jnp.argmin(sel)
        curr = ids[best]
        visited = visited.at[best].set(True)

        nb = graph.nbrs[jnp.clip(curr, 0)]
        valid = (nb >= 0) & graph.alive[jnp.clip(nb, 0)]
        xv, hit = _gather_tiered(graph, cache, nb)
        d = _sqdist(xv, q)
        # drop invalid + already-in-pool duplicates
        in_pool = (nb[:, None] == ids[None, :]).any(1)
        d = jnp.where(valid & ~in_pool, d, INF)

        all_ids = jnp.concatenate([ids, nb])
        all_d = jnp.concatenate([dists, d])
        all_vis = jnp.concatenate([visited, jnp.zeros((R,), bool)])
        keep = jnp.argsort(all_d)[:L]
        ids, dists, visited = all_ids[keep], all_d[keep], all_vis[keep]

        acc_ids = acc_ids.at[it].set(jnp.where(valid, nb, -1))
        acc_hit = acc_hit.at[it].set(hit & valid)
        return it + 1, ids, dists, visited, acc_ids, acc_hit

    it, ids, dists, visited, acc_ids, acc_hit = jax.lax.while_loop(
        cond, body, (jnp.int32(0), ids0, dist0, visited0, acc_ids0, acc_hit0))

    topk_ids = jnp.where(jnp.isfinite(dists[:sp.k]), ids[:sp.k], -1)
    return SearchResult(topk_ids, dists[:sp.k],
                        acc_ids.reshape(-1), acc_hit.reshape(-1), it)


@partial(jax.jit, static_argnames=("sp",))
def search_batch(state: IndexState, queries, key, sp: SearchParams
                 ) -> SearchResult:
    """Batched ANNS. queries [B, D]. Entry points are random (paper §4.2:
    GPU-friendly, no seed maintenance under updates)."""
    B = queries.shape[0]
    n = jnp.maximum(state.graph.n, 1)
    entries = jax.random.randint(key, (B, sp.pool), 0, n, dtype=jnp.int32)
    res = jax.vmap(lambda q, e: _search_one(state.graph, state.cache, q, e, sp)
                   )(queries.astype(jnp.float32), entries)
    return res


def brute_force_topk(graph: GraphState, queries, k):
    """Exact ground truth over alive vectors (recall oracle)."""
    d = (jnp.sum(queries ** 2, 1, keepdims=True)
         - 2.0 * queries @ graph.vectors.T
         + jnp.sum(graph.vectors ** 2, 1)[None, :])
    d = jnp.where(graph.alive[None, :], d, INF)
    nd, idx = jax.lax.top_k(-d, k)
    return idx, -nd


def recall_at_k(found_ids, true_ids):
    """found/true [B, k] -> mean fraction of true ids found."""
    hits = (found_ids[:, :, None] == true_ids[:, None, :]).any(1)
    return jnp.mean(hits.astype(jnp.float32))
