"""Durability layer for the tiered store: write-ahead log + epoch-fenced
snapshots (ROADMAP "Durability & drift", durability half).

The paper's streaming story (§5) assumes the index survives the process;
this module makes the disk tier's truth crash-consistent so everything
device-resident — the WAVP exact cache, the PQ code mirror, the TopoCache
— can stay a *pure cache*, rebuilt at recovery (the FusionANNS split:
SSD-resident truth, GPU-resident accelerant).

Write protocol (update stream, serialized by the engine):

1. prepare — compute the op's full effect (candidate search, selected
   rows, reverse-edge triplets) against the *unmutated* store;
2. WAL append — one CRC-framed record per logical op, fsync batched by
   ``group_commit`` (records the OS buffered but never fsynced survive a
   process kill; only power/OS failure can lose the tail, and the CRC
   framing truncates any torn tail cleanly either way);
3. apply — mutate the store through the SAME apply function recovery
   replays, so a recovered index is bit-identical to an uninterrupted
   run by construction.

Snapshot protocol (``publish_snapshot``): fsync the WAL and both memmaps,
write ``snapshot-<epoch>.npz`` (adjacency rows [0, n), alive/e_in/version,
PQ codebook + codes — vectors are immutable per id and already durable in
the memmap), fsync + atomic-rename it, open a fresh WAL segment, then
atomically rename ``manifest.json`` to point at the pair. A crash anywhere
in the sequence leaves the previous manifest intact and its snapshot +
WAL segment untouched — recovery is always from the last *published*
epoch.

Recovery (``recover``): verify the snapshot against the manifest's CRC,
restore the metadata directory and adjacency rows (rows past the torn
tail of a crashed insert are cleared — the memmap beyond the snapshot's
high-water mark is not trusted), then replay the WAL segment through the
apply functions, truncating at the first record whose frame fails the
CRC/length check.

Fault injection: ``set_crash_hook`` installs a process-wide hook that
``crash_point(name)`` calls at the named crash sites (post_wal_append,
mid_memmap_write, pre_manifest_rename, mid_consolidation_merge);
``tests/faultinject.py`` arms it with an ``os._exit`` to simulate kill -9.
"""
from __future__ import annotations

import io
import json
import os
import pickle
import struct
import threading
import zlib
from typing import Callable, Optional

import numpy as np

MAGIC = b"SVWL"
_HDR = struct.Struct("<4sBQII")       # magic, rtype, op_seq, payload_len, crc
MANIFEST = "manifest.json"
MANIFEST_FORMAT = 1

REC_INSERT = 1
REC_DELETE = 2
REC_CONSOLIDATE = 3


class WALError(RuntimeError):
    """Base class for durability-layer failures."""


class WALWriteError(WALError):
    """The WAL device failed an append/sync; the op was NOT applied.
    The engine degrades to read-only instead of crashing."""


class WALCorruptionError(WALError):
    """Manifest/snapshot failed validation at recovery."""


# ---------------------------------------------------------------------------
# Crash-point hooks (fault injection)
# ---------------------------------------------------------------------------

_CRASH_HOOK: Optional[Callable[[str], None]] = None

CRASH_POINTS = ("post_wal_append", "mid_memmap_write",
                "pre_manifest_rename", "mid_consolidation_merge")


def set_crash_hook(hook: Optional[Callable[[str], None]]) -> None:
    """Install (or clear, with None) the process-wide crash hook. The hook
    receives the crash-point name on every pass through an instrumented
    site and decides whether to die (``tests/faultinject.py``)."""
    global _CRASH_HOOK
    _CRASH_HOOK = hook


def crash_point(name: str) -> None:
    """Named crash site — free when no hook is installed."""
    hook = _CRASH_HOOK
    if hook is not None:
        hook(name)


# ---------------------------------------------------------------------------
# Record framing
# ---------------------------------------------------------------------------

def _frame(rtype: int, op_seq: int, payload: dict) -> bytes:
    body = pickle.dumps(payload, protocol=4)
    crc = zlib.crc32(struct.pack("<BQI", rtype, op_seq, len(body)) + body)
    return _HDR.pack(MAGIC, rtype, op_seq, len(body), crc) + body


def read_records(path: str):
    """Parse a WAL segment. Returns ``(records, valid_len)`` where records
    is ``[(rtype, op_seq, payload), ...]`` and ``valid_len`` is the byte
    offset of the first frame that fails the magic/length/CRC check — the
    torn tail a crashed group-commit batch may have left. Callers truncate
    the file to ``valid_len`` before appending again."""
    with open(path, "rb") as f:
        data = f.read()
    records, off = [], 0
    while off + _HDR.size <= len(data):
        magic, rtype, seq, plen, crc = _HDR.unpack_from(data, off)
        if magic != MAGIC or off + _HDR.size + plen > len(data):
            break
        body = data[off + _HDR.size: off + _HDR.size + plen]
        if zlib.crc32(struct.pack("<BQI", rtype, seq, plen) + body) != crc:
            break
        records.append((rtype, seq, pickle.loads(body)))
        off += _HDR.size + plen
    return records, off


def _fsync_dir(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


class WriteAheadLog:
    """Append-only CRC-framed log with group-commit batching.

    ``append`` assigns a monotone ``op_seq`` (continued across segment
    rotations via ``start_seq``), writes the frame immediately and defers
    the fsync until ``group_commit`` records are pending — the classic
    group-commit throughput trade. A failed write/sync poisons the log
    (``failed``) so the engine can degrade to read-only; the store was
    not touched for the failed op (WAL-before-write).
    """

    def __init__(self, path: str, *, group_commit: int = 8,
                 start_seq: int = 1):
        self.path = path
        self.group_commit = max(1, int(group_commit))
        self.appended = 0
        self.synced = 0
        self.failed: Optional[str] = None
        self._next_seq = int(start_seq)
        self._pending = 0
        self._lock = threading.Lock()
        # unbuffered: every append hits the OS immediately, so a process
        # kill (as opposed to power loss) can never lose an appended
        # record to a userspace buffer — the contract the fault-injection
        # matrix (kill -9 at post_wal_append) relies on
        self._f = open(path, "ab", buffering=0)
        # the segment must exist durably before a manifest references it
        os.fsync(self._f.fileno())
        _fsync_dir(os.path.dirname(os.path.abspath(path)))

    @property
    def last_seq(self) -> int:
        return self._next_seq - 1

    @property
    def closed(self) -> bool:
        return self._f.closed

    def append(self, rtype: int, payload: dict) -> int:
        with self._lock:
            if self.failed:
                raise WALWriteError(self.failed)
            seq = self._next_seq
            try:
                self._f.write(_frame(rtype, seq, payload))
                self._pending += 1
                if self._pending >= self.group_commit:
                    self._f.flush()
                    os.fsync(self._f.fileno())
                    self.synced += self._pending
                    self._pending = 0
            except (OSError, ValueError) as e:
                self.failed = f"wal append failed: {e}"
                raise WALWriteError(self.failed) from e
            self._next_seq = seq + 1
            self.appended += 1
        crash_point("post_wal_append")
        return seq

    def sync(self) -> None:
        with self._lock:
            if self.failed:
                raise WALWriteError(self.failed)
            try:
                self._f.flush()
                os.fsync(self._f.fileno())
                self.synced += self._pending
                self._pending = 0
            except (OSError, ValueError) as e:
                self.failed = f"wal sync failed: {e}"
                raise WALWriteError(self.failed) from e

    def close(self) -> None:
        if not self._f.closed:
            if not self.failed:
                try:
                    self.sync()
                except WALWriteError:
                    pass
            self._f.close()


# ---------------------------------------------------------------------------
# Manifest + snapshot publication
# ---------------------------------------------------------------------------

def _segment_name(epoch: int) -> str:
    return f"wal-{epoch:08d}.log"


def _snapshot_name(epoch: int) -> str:
    return f"snapshot-{epoch:08d}.npz"


def load_manifest(dirpath: Optional[str]) -> Optional[dict]:
    """The last published durable epoch, or None when the directory holds
    no recoverable index."""
    if not dirpath:
        return None
    path = os.path.join(dirpath, MANIFEST)
    if not os.path.exists(path):
        return None
    with open(path, "r") as f:
        man = json.load(f)
    if man.get("format") != MANIFEST_FORMAT:
        raise WALCorruptionError(
            f"manifest format {man.get('format')!r} unsupported "
            f"(expected {MANIFEST_FORMAT})")
    return man


def publish_snapshot(dirpath: str, backend, prev_wal: Optional[WriteAheadLog],
                     *, group_commit: int = 8, chunk: int = 8192):
    """Publish the backend's current state as the new durable epoch.
    Returns ``(manifest, new_wal)``; the previous WAL segment is closed
    and deleted once the manifest rename lands. Caller holds the engine's
    update lock (the snapshot must be a consistent cut of the update
    stream; concurrent searches only promote identical data)."""
    os.makedirs(dirpath, exist_ok=True)
    prev = load_manifest(dirpath)
    epoch = (int(prev["epoch"]) + 1) if prev else 0
    if prev_wal is not None:
        prev_wal.sync()
    store = backend.store
    store.disk.flush()

    n = int(backend.n)
    rows = np.empty((n, backend.degree), np.int32)
    for s in range(0, n, chunk):
        ids = np.arange(s, min(s + chunk, n))
        rows[ids] = store.peek_rows(ids)
    op_seq = prev_wal.last_seq if prev_wal is not None else 0
    arrays = dict(nbrs=rows, alive=backend.alive[:n].copy(),
                  version=backend.version[:n].copy(),
                  e_in=backend.e_in[:n].copy(),
                  n=np.asarray(n, np.int64),
                  op_seq=np.asarray(op_seq, np.int64))
    pq_meta = None
    if backend.pq is not None:
        from repro.core import quant
        arrays["pq_centroids"] = quant.codebook_to_array(backend.pq.codebook)
        arrays["pq_codes"] = backend.pq.snapshot(n)
        pq_meta = {"m": backend.pq.m, "bits": backend.pq.bits}
    attrs_meta = None
    if backend.attrs is not None:
        tags, nums = backend.attrs.snapshot(n)
        arrays["attr_tags"], arrays["attr_nums"] = tags, nums
        attrs_meta = backend.attrs.schema.to_meta()

    snap_name = _snapshot_name(epoch)
    snap_tmp = os.path.join(dirpath, snap_name + ".tmp")
    buf = io.BytesIO()
    np.savez(buf, **arrays)
    raw = buf.getvalue()
    with open(snap_tmp, "wb") as f:
        f.write(raw)
        f.flush()
        os.fsync(f.fileno())
    os.replace(snap_tmp, os.path.join(dirpath, snap_name))
    _fsync_dir(dirpath)

    wal_name = _segment_name(epoch)
    new_wal = WriteAheadLog(os.path.join(dirpath, wal_name),
                            group_commit=group_commit, start_seq=op_seq + 1)
    manifest = {
        "format": MANIFEST_FORMAT, "epoch": epoch, "op_seq": op_seq,
        "n": n, "capacity": int(backend.capacity), "dim": int(backend.dim),
        "degree": int(backend.degree), "snapshot": snap_name,
        "snapshot_crc": zlib.crc32(raw), "wal": wal_name, "pq": pq_meta,
        "attrs": attrs_meta,
    }
    crash_point("pre_manifest_rename")
    man_tmp = os.path.join(dirpath, MANIFEST + ".tmp")
    with open(man_tmp, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(man_tmp, os.path.join(dirpath, MANIFEST))
    _fsync_dir(dirpath)

    if prev_wal is not None:
        prev_wal.close()
    _cleanup_stale(dirpath, manifest)
    return manifest, new_wal


def _cleanup_stale(dirpath: str, manifest: dict) -> None:
    """Drop snapshot/WAL files the published manifest no longer
    references (previous epochs, or orphans from a crash mid-publish)."""
    keep = {manifest["snapshot"], manifest["wal"]}
    for name in os.listdir(dirpath):
        if name in keep:
            continue
        if (name.startswith("snapshot-") or name.startswith("wal-")):
            try:
                os.remove(os.path.join(dirpath, name))
            except OSError:        # best effort: stale files are inert
                pass


# ---------------------------------------------------------------------------
# Recovery
# ---------------------------------------------------------------------------

def _replay(backend, records) -> None:
    from repro.core import mvcc, update
    for rtype, _seq, p in records:
        if rtype == REC_INSERT:
            rev = update.RevLog(p["rev_v"], p["rev_vn"], p["rev_d"])
            # attribute columns ride newer records only; .get keeps
            # pre-attribute WAL segments replayable
            update.apply_insert_tiered(backend, p["ids"], p["vecs"],
                                       p["sel"], rev,
                                       tags=p.get("tags"),
                                       nums=p.get("nums"))
        elif rtype == REC_DELETE:
            update.apply_delete_tiered(backend, p["ids"])
        elif rtype == REC_CONSOLIDATE:
            mvcc.apply_merge_edits(backend,
                                   list(zip(p["ids"], p["rows"])))
        else:
            raise WALCorruptionError(f"unknown WAL record type {rtype}")


def recover(dirpath: str, *, host_window: int, group_commit: int = 8,
            chunk: int = 8192):
    """Open the last published epoch and roll the WAL forward. Returns
    ``(backend, wal, report)``: a fully rebuilt ``TieredBackend`` (PQ lane
    attached when the manifest records one; device mirrors are the
    engine's to re-warm — they are pure caches), the reopened WAL
    positioned after the last valid record, and a report dict."""
    from repro.core.tiers import DiskTier, TieredBackend, TieredStore
    man = load_manifest(dirpath)
    if man is None:
        raise WALCorruptionError(f"no manifest in {dirpath!r}")
    spath = os.path.join(dirpath, man["snapshot"])
    with open(spath, "rb") as f:
        raw = f.read()
    if zlib.crc32(raw) != man["snapshot_crc"]:
        raise WALCorruptionError(
            f"snapshot {man['snapshot']} failed CRC validation")
    snap = np.load(io.BytesIO(raw))
    cap, dim, R = int(man["capacity"]), int(man["dim"]), int(man["degree"])
    n = int(snap["n"])

    disk = DiskTier(dirpath, cap, dim, R, create=False)
    # adjacency truth comes from the snapshot: rows a killed writer tore
    # mid-memmap-write (including any past the durable high-water mark)
    # are overwritten/cleared before replay re-applies the logged ops
    rows = np.asarray(snap["nbrs"], np.int32)
    for s in range(0, n, chunk):
        disk.nbr[s:min(s + chunk, n)] = rows[s:min(s + chunk, n)]
    for s in range(n, cap, chunk):
        disk.nbr[s:min(s + chunk, cap)] = -1

    backend = TieredBackend(TieredStore(disk, host_window), n)
    backend.alive[:n] = snap["alive"]
    backend.version[:n] = snap["version"]
    backend.e_in[:n] = snap["e_in"]
    if man.get("pq"):
        from repro.core import quant
        cb = quant.codebook_from_array(np.asarray(snap["pq_centroids"]))
        backend.attach_pq(quant.PQCodes(cb, cap,
                                        codes=np.asarray(snap["pq_codes"])))
    # pre-attribute manifests (no "attrs" key) recover without a store;
    # the engine attaches an empty one if its config declares a schema
    if man.get("attrs"):
        from repro.core.filters import AttributeSchema
        from repro.core.tiers import AttributeStore
        schema = AttributeSchema.from_meta(man["attrs"])
        backend.attach_attrs(AttributeStore(
            schema, cap, tags=np.asarray(snap["attr_tags"]),
            nums=np.asarray(snap["attr_nums"])))

    wpath = os.path.join(dirpath, man["wal"])
    truncated = 0
    if os.path.exists(wpath):
        records, valid = read_records(wpath)
        truncated = os.path.getsize(wpath) - valid
        if truncated:
            os.truncate(wpath, valid)
    else:                           # segment lost entirely: nothing to roll
        records = []
    _replay(backend, records)
    last_seq = records[-1][1] if records else int(man["op_seq"])
    wal = WriteAheadLog(wpath, group_commit=group_commit,
                        start_seq=last_seq + 1)
    backend.wal = wal
    report = {"epoch": int(man["epoch"]), "snapshot_seq": int(man["op_seq"]),
              "replayed": len(records), "last_seq": last_seq,
              "truncated_bytes": int(truncated)}
    return backend, wal, report
