"""SLO-aware serving tier: per-tenant deadline admission, weighted-fair
draining, p99-targeted coalescing control and graceful degradation
(paper §4.4 "real-time coordination with adaptive resource management";
the per-stream admission / deadline-awareness / load-conditioned scaling
follows the real-time adaptive multi-stream production design, and the
degrade-quality-before-shedding order follows FusionANNS's cooperative
CPU/GPU scheduling).

The tier sits between ``engine.search``/``submit_search`` and the
coalescing dispatcher:

* **Admission** (``ServingTier.offer``): every request carries a tenant
  id (default tenant when none) and an optional absolute deadline. Each
  tenant owns a FIFO; the dispatcher drains across tenants by **stride
  scheduling** (weighted fair: a tenant's virtual time advances by
  rows/weight per admitted request), so one hot tenant can saturate only
  its weight share of dispatch rows and can never starve the others.
* **Deadline admission**: at drain time a request whose deadline cannot
  be met even if dispatched immediately (``now + est_dispatch >
  deadline``) is skipped-and-failed with ``DeadlineMissError`` instead
  of wasting a dispatch on an answer the caller already abandoned.
* **Load shedding — last resort**: admission sheds (fails the future
  with ``LoadShedError``) only when the tenant's *modeled wait* — its
  queued rows over its weighted-fair share of the measured service rate
  — exceeds ``shed_at`` x the p99 target **and** degradation is already
  at its deepest level. Quality degrades before any request is dropped.
* **Graceful degradation** (``PressureController`` + ``degrade_params``):
  a pressure signal (modeled queue wait / p99 target) walks through
  ``degrade_order``, shrinking search-quality knobs through
  ``SearchParams`` overrides — re-rank depth first, then beam width
  (hop budget riding along so the round count stays constant and the
  per-round candidate width halves), then the fused round budget. Levels
  restore one at a time after ``restore_after`` consecutive calm
  dispatches (hysteresis: no flapping at a threshold).
* **p99-targeted window control**: the dispatcher keeps a reservoir of
  per-request end-to-end latencies; the coalescing window widens only
  while the observed p99 is under ``target_p99`` (and requests actually
  merged), and shrinks when p99 overshoots or a dispatch went out
  uncoalesced — replacing the global merge-rate halve/double heuristic
  that let a hot caller widen everyone's window unboundedly.

Everything here is host-side scheduling state: one lock (``self.cv``)
guards the queues, counters and model, and **every queue pop happens
under it** — the shutdown drain is mutually exclusive with the
dispatcher's pops by construction (the coalescer shutdown race fix).
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.core.types import SearchParams

DEFAULT_TENANT = "default"


class SLOError(RuntimeError):
    """Base for admission-control failures surfaced through futures."""


class LoadShedError(SLOError):
    """Admission shed the request: the tenant's modeled queue wait
    exceeded the SLO with degradation already at its deepest level."""


class DeadlineMissError(SLOError):
    """The dispatcher skipped the request: its deadline could not be met
    even if dispatched immediately."""


class RateLimitError(SLOError):
    """Admission rejected the request: the tenant's token bucket
    (``SLOPolicy.tenant_rate_limits``) is empty."""


_NO_KEY = object()   # _pop_next sentinel: no filter-compatibility pin


@dataclass(frozen=True)
class SLOPolicy:
    """Knobs of the serving tier (engine config: ``slo_*``)."""

    target_p99: float = 0.05        # per-request p99 target (seconds);
    #                                 <= 0 disables the SLO machinery
    #                                 (no pressure, degradation or
    #                                 shedding; admission still runs
    #                                 weighted-fair and deadlines still
    #                                 apply when a request carries one)
    default_deadline: float = 0.0   # seconds after submit applied when a
    #                                 request carries none; 0 = no deadline
    tenant_weights: Optional[dict] = None   # tenant -> fair-share weight
    default_weight: float = 1.0     # weight of unlisted tenants
    degrade_order: tuple = ("rerank_depth", "beam", "fused_rounds")
    degrade_at: float = 0.5         # pressure (modeled wait / target)
    #                                 where level 1 engages; deeper levels
    #                                 space evenly up to shed_at
    shed_at: float = 1.0            # modeled-wait/target above which a
    #                                 maxed-out-degradation tenant sheds
    restore_after: int = 4          # consecutive calm dispatches per
    #                                 one-level restore (hysteresis)
    reservoir: int = 512            # latency samples kept per reservoir
    tenant_rate_limits: Optional[dict] = None   # tenant -> requests/s,
    #                                 or (rate, burst); absent = unlimited.
    #                                 Token bucket at offer: an empty
    #                                 bucket rejects with RateLimitError
    #                                 (counted per tenant in stats())

    @property
    def enabled(self) -> bool:
        return self.target_p99 > 0

    @property
    def n_levels(self) -> int:
        return len(self.degrade_order)

    def weight(self, tenant: str) -> float:
        w = (self.tenant_weights or {}).get(tenant, self.default_weight)
        if w <= 0:
            raise ValueError(f"tenant weight must be > 0, got {w} for "
                             f"{tenant!r}")
        return float(w)

    def rate_limit(self, tenant: str):
        """``(rate, burst)`` for ``tenant`` or None (unlimited). A bare
        rate gets ``burst = max(1, rate)`` — a one-second burst window,
        never below one admittable request."""
        rl = (self.tenant_rate_limits or {}).get(tenant)
        if rl is None:
            return None
        if isinstance(rl, (tuple, list)):
            rate, burst = float(rl[0]), float(rl[1])
        else:
            rate, burst = float(rl), max(1.0, float(rl))
        if rate <= 0 or burst <= 0:
            raise ValueError(f"rate limit for {tenant!r} must be > 0, "
                             f"got rate={rate}, burst={burst}")
        return rate, burst

    def level_threshold(self, level: int) -> float:
        """Pressure at which ``level`` engages (levels 1..n_levels spread
        evenly over [degrade_at, shed_at))."""
        n = max(self.n_levels, 1)
        return self.degrade_at + (level - 1) * \
            max(self.shed_at - self.degrade_at, 0.0) / n


class LatencyReservoir:
    """Fixed-size ring of latency samples with percentile reads. The
    reservoir keeps the newest ``cap`` samples: serving control must
    react to the current regime, not the run's whole history."""

    __slots__ = ("_buf", "_n", "_i")

    def __init__(self, cap: int = 512):
        self._buf = np.zeros(max(1, cap), np.float64)
        self._n = 0
        self._i = 0

    def add(self, x: float):
        self._buf[self._i] = x
        self._i = (self._i + 1) % len(self._buf)
        self._n = min(self._n + 1, len(self._buf))

    def __len__(self) -> int:
        return self._n

    def quantile(self, q: float) -> Optional[float]:
        """q in [0, 100]; None while empty."""
        if self._n == 0:
            return None
        return float(np.percentile(self._buf[:self._n], q))


class PressureController:
    """Hysteretic pressure -> degradation-level mapping. Escalates
    immediately when pressure crosses a level's threshold (overload must
    be answered now); de-escalates one level at a time only after
    ``restore_after`` consecutive updates below the current level's
    threshold (a single calm dispatch under a bursty arrival process is
    noise, not recovery)."""

    def __init__(self, policy: SLOPolicy):
        self.policy = policy
        self.level = 0
        self._calm = 0

    def _want(self, pressure: float) -> int:
        want = 0
        for lvl in range(1, self.policy.n_levels + 1):
            if pressure >= self.policy.level_threshold(lvl):
                want = lvl
        return want

    def update(self, pressure: float) -> int:
        want = self._want(pressure)
        if want > self.level:
            self.level = want
            self._calm = 0
        elif want < self.level:
            self._calm += 1
            if self._calm >= self.policy.restore_after:
                self.level -= 1
                self._calm = 0
        else:
            self._calm = 0
        return self.level


def degrade_params(sp: SearchParams, rerank_depth: int, level: int,
                   order: tuple = ("rerank_depth", "beam", "fused_rounds"),
                   ) -> tuple:
    """Search-quality knobs at degradation ``level``: each engaged stage
    of ``order`` halves one knob, cumulatively. Pure — restoring is just
    dispatching at a lower level again. Returns ``(sp, rerank_depth)``.

    * ``"rerank_depth"``: halve the exactly re-ranked pool prefix
      (floor ``sp.k``; the 0 = whole-pool sentinel degrades from
      ``sp.pool``). PQ answers lean harder on the ADC ordering.
    * ``"beam"``: halve beam AND the hop budget together (floors 4 /
      1 round) — the round count stays constant while the per-round
      candidate width halves, which is what actually halves executor
      work (halving beam alone would double the round count).
    * ``"fused_rounds"``: halve the hop budget again (floor one beam's
      worth), halving how many rounds the fused loop runs per query.
    """
    if level <= 0:
        return sp, rerank_depth
    from repro.core.search import effective_rerank_depth
    for knob in order[:level]:
        if knob == "rerank_depth":
            base = effective_rerank_depth(rerank_depth, sp.k, sp.pool)
            rerank_depth = max(sp.k, base // 2)
        elif knob == "beam":
            new_beam = max(4, sp.beam // 2)
            sp = sp._replace(beam=new_beam,
                             max_iters=max(new_beam, sp.max_iters // 2))
        elif knob == "fused_rounds":
            sp = sp._replace(max_iters=max(max(1, sp.beam),
                                           sp.max_iters // 2))
        else:
            raise ValueError(f"unknown degrade_order stage {knob!r}")
    return sp, rerank_depth


class _TenantState:
    """Per-tenant admission queue + accounting (all fields guarded by
    the owning ``ServingTier``'s lock)."""

    __slots__ = ("name", "weight", "q", "queued_rows", "vtime",
                 "submitted", "completed", "shed", "deadline_misses",
                 "lat", "tokens", "rl_t", "rate_limited")

    def __init__(self, name: str, weight: float, reservoir: int):
        self.name = name
        self.weight = weight
        self.q: deque = deque()
        self.queued_rows = 0
        self.vtime = 0.0        # stride-scheduling virtual time
        self.submitted = 0
        self.completed = 0
        self.shed = 0
        self.deadline_misses = 0
        self.lat = LatencyReservoir(reservoir)
        self.tokens = 0.0       # token bucket (lazily filled at first offer)
        self.rl_t: Optional[float] = None   # last refill timestamp
        self.rate_limited = 0


class ServingTier:
    """Admission + fairness + pressure state shared with the coalescing
    dispatcher. The dispatcher calls ``collect`` (weighted-fair batch
    assembly under the lock) and ``complete`` (latency/throughput model
    + pressure controller update); clients call ``offer``."""

    def __init__(self, policy: Optional[SLOPolicy] = None):
        self.policy = policy or SLOPolicy()
        self.lock = threading.Lock()
        self.cv = threading.Condition(self.lock)
        self.closed = False
        self.tenants: dict[str, _TenantState] = {}
        self.controller = PressureController(self.policy)
        self.lat = LatencyReservoir(self.policy.reservoir)
        self._queued_requests = 0
        self._queued_rows = 0
        self.rows_per_s: Optional[float] = None   # EWMA service rate
        self.est_dispatch_s: Optional[float] = None  # EWMA dispatch wall
        self.shed_total = 0
        self.deadline_miss_total = 0
        self.rate_limited_total = 0
        self.overshoot_avoided = 0   # admissions deferred at the batch cap
        self.pressure = 0.0

    # -- client side ----------------------------------------------------
    def _tenant(self, name: str) -> _TenantState:
        ts = self.tenants.get(name)
        if ts is None:
            ts = _TenantState(name, self.policy.weight(name),
                              self.policy.reservoir)
            # a fresh (or long-idle) tenant must not owe the others the
            # whole history of virtual time it never consumed
            ts.vtime = self._min_vtime()
            self.tenants[name] = ts
        return ts

    def _min_vtime(self) -> float:
        act = [t.vtime for t in self.tenants.values() if t.q]
        return min(act) if act else 0.0

    def _fair_wait(self, ts: _TenantState) -> float:
        """Modeled queue wait for one more row of ``ts``: its queued rows
        over its weighted-fair share of the measured service rate. The
        share is computed over tenants that are actually contending
        (non-empty queues), so an alone-in-the-queue tenant models the
        full rate."""
        if self.rows_per_s is None or self.rows_per_s <= 0:
            return 0.0
        active_w = sum(t.weight for t in self.tenants.values()
                       if t.q or t is ts)
        share = ts.weight / max(active_w, ts.weight)
        return ts.queued_rows / (share * self.rows_per_s)

    def offer(self, fut) -> bool:
        """Admit ``fut`` (a ``_SearchFuture`` carrying ``tenant``,
        ``deadline`` and ``queries``), or shed it. Shedding completes the
        future with ``LoadShedError`` and returns False — admission
        failures ride the future so sync and async callers see one
        failure mode. Raises RuntimeError after ``close``."""
        with self.cv:
            if self.closed:
                raise RuntimeError(
                    "CoalescingScheduler is stopped (engine closed); no "
                    "further searches accepted")
            ts = self._tenant(fut.tenant)
            ts.submitted += 1
            # token-bucket rate limit (per tenant, requests/s): refill
            # from wall time, then spend one token or reject. Runs before
            # the shed check — a limit violation is the tenant's own
            # doing and must not depend on global pressure state.
            rl = self.policy.rate_limit(ts.name)
            if rl is not None:
                rate, burst = rl
                now = time.perf_counter()
                if ts.rl_t is None:
                    ts.tokens = burst           # full bucket at first sight
                else:
                    ts.tokens = min(burst,
                                    ts.tokens + (now - ts.rl_t) * rate)
                ts.rl_t = now
                if ts.tokens < 1.0:
                    ts.rate_limited += 1
                    self.rate_limited_total += 1
                    fut.error = RateLimitError(
                        f"tenant {ts.name!r} rate-limited: bucket empty "
                        f"(rate {rate:g}/s, burst {burst:g})")
                    fut._event.set()
                    return False
                ts.tokens -= 1.0
            wait = self._fair_wait(ts)
            if (self.policy.enabled
                    and self.controller.level >= self.policy.n_levels
                    and wait > self.policy.shed_at * self.policy.target_p99):
                # last resort: quality degradation is already maxed and
                # this tenant's fair-share backlog still models past the
                # SLO — admitting would only miss, so fail fast
                ts.shed += 1
                self.shed_total += 1
                fut.error = LoadShedError(
                    f"tenant {ts.name!r} shed: modeled queue wait "
                    f"{wait * 1e3:.1f} ms exceeds "
                    f"{self.policy.shed_at:.2f} x target p99 "
                    f"{self.policy.target_p99 * 1e3:.1f} ms at max "
                    f"degradation")
                fut._event.set()
                return False
            if fut.deadline is None and self.policy.default_deadline > 0:
                fut.deadline = fut.submitted + self.policy.default_deadline
            ts.q.append(fut)
            ts.queued_rows += len(fut.queries)
            self._queued_requests += 1
            self._queued_rows += len(fut.queries)
            self.cv.notify_all()
        return True

    # -- dispatcher side ------------------------------------------------
    def _pop_next(self, rows: int, max_batch: int, fkey=_NO_KEY):
        """One weighted-fair pop (caller holds the lock): pick the
        non-empty tenant with the least virtual time, fail-and-skip
        heads whose deadline is already unmeetable, and refuse (peek,
        don't admit) a head that would push the batch past ``max_batch``
        — the pow2 padding bucket must not jump a size because one more
        request squeezed in after the cap was reached.

        ``fkey`` pins the batch's filter-spec compatibility class: only
        heads whose ``fkey`` matches may join (one executor dispatch
        evaluates ONE predicate). Incompatible heads are left queued —
        they lead the next batch — but their tenants are *skipped*, in
        vtime order, so a filtered hot tenant can't stall everyone."""
        est = self.est_dispatch_s or 0.0
        while True:
            act = sorted((t for t in self.tenants.values() if t.q),
                         key=lambda t: t.vtime)
            if not act:
                return None
            rescan = False
            for ts in act:
                fut = ts.q[0]
                r = len(fut.queries)
                now = time.perf_counter()
                if fut.deadline is not None and now + est > fut.deadline:
                    # skip-and-fail: the answer would arrive past the
                    # deadline even if dispatched right now
                    ts.q.popleft()
                    ts.queued_rows -= r
                    self._queued_requests -= 1
                    self._queued_rows -= r
                    ts.deadline_misses += 1
                    self.deadline_miss_total += 1
                    fut.error = DeadlineMissError(
                        f"tenant {ts.name!r} request missed its deadline "
                        f"before dispatch "
                        f"({(now - fut.submitted) * 1e3:.1f} "
                        f"ms queued, est dispatch {est * 1e3:.1f} ms)")
                    fut._event.set()
                    rescan = True    # queue changed: re-derive the order
                    break
                if fkey is not _NO_KEY \
                        and getattr(fut, "fkey", None) != fkey:
                    continue        # incompatible head: try next tenant
                if rows > 0 and rows + r > max_batch:
                    self.overshoot_avoided += 1
                    return None     # re-queued for the next dispatch
                ts.q.popleft()
                ts.queued_rows -= r
                self._queued_requests -= 1
                self._queued_rows -= r
                ts.vtime += r / ts.weight
                return fut
            if not rescan:
                return None

    def collect(self, max_batch: int, window: float, stop) -> list:
        """Assemble one dispatch batch: block (briefly) for the first
        request, then admit weighted-fair until the adaptive window
        closes, the batch fills, or the next head would overshoot the
        cap. Every pop happens under the lock, so a concurrent shutdown
        drain can never double-complete a future. Returns possibly-empty
        list (caller re-checks its stop flag)."""
        with self.cv:
            if self.closed or stop.is_set():
                return []   # shutdown owns the queue now (drain)
            if self._queued_requests == 0:
                self.cv.wait(timeout=0.05)
            if self.closed:
                return []
            first = self._pop_next(0, max_batch)
            if first is None:
                return []
            batch = [first]
            rows = len(first.queries)
            fkey = getattr(first, "fkey", None)   # batch's filter class
            deadline = time.perf_counter() + window
            while rows < max_batch and not self.closed \
                    and not stop.is_set():
                nxt = self._pop_next(rows, max_batch, fkey=fkey)
                if nxt is not None:
                    batch.append(nxt)
                    rows += len(nxt.queries)
                    continue
                if self._queued_requests > 0:
                    break       # head would overshoot the cap: dispatch
                left = deadline - time.perf_counter()
                if left <= 0:
                    break
                self.cv.wait(timeout=left)
            return batch

    def complete(self, batch: list, rows: int, dispatch_s: float,
                 ok: bool = True):
        """Post-dispatch accounting: feed the latency reservoirs, update
        the service-rate model and drive the pressure controller. An
        errored dispatch (``ok=False``) still drives the controller but
        must not feed the latency/throughput model. Returns the
        (possibly new) degradation level for the NEXT dispatch."""
        now = time.perf_counter()
        with self.cv:
            if ok and dispatch_s > 0:
                rate = rows / dispatch_s
                self.rows_per_s = rate if self.rows_per_s is None else \
                    0.8 * self.rows_per_s + 0.2 * rate
                self.est_dispatch_s = dispatch_s \
                    if self.est_dispatch_s is None else \
                    0.8 * self.est_dispatch_s + 0.2 * dispatch_s
            if ok:
                for fut in batch:
                    ts = self._tenant(fut.tenant)
                    lat = now - fut.submitted
                    ts.completed += 1
                    ts.lat.add(lat)
                    self.lat.add(lat)
            if self.policy.enabled and self.rows_per_s:
                self.pressure = (self._queued_rows / self.rows_per_s
                                 / self.policy.target_p99)
            else:
                self.pressure = 0.0
            return self.controller.update(self.pressure)

    def set_policy(self, policy: SLOPolicy):
        """Swap the serving policy live (the SLO bench calibrates a
        sustainable rate first, then retargets). Resets the pressure
        controller — thresholds moved, the old level is meaningless —
        and re-resolves every known tenant's fair-share weight; queues,
        counters and the latency/throughput model carry over."""
        with self.cv:
            self.policy = policy
            self.controller = PressureController(policy)
            for ts in self.tenants.values():
                ts.weight = policy.weight(ts.name)
                ts.rl_t = None      # limits moved: refill at next offer

    @property
    def level(self) -> int:
        return self.controller.level

    def request_p99(self) -> Optional[float]:
        with self.lock:
            return self.lat.quantile(99)

    # -- shutdown -------------------------------------------------------
    def close(self):
        with self.cv:
            self.closed = True
            self.cv.notify_all()

    def drain(self, error: Exception) -> int:
        """Fail every still-queued future with ``error``. Mutually
        exclusive with the dispatcher's pops (same lock + closed check),
        so a future is completed exactly once. Returns #failed."""
        n = 0
        with self.cv:
            for ts in self.tenants.values():
                while ts.q:
                    fut = ts.q.popleft()
                    ts.queued_rows -= len(fut.queries)
                    self._queued_requests -= 1
                    self._queued_rows -= len(fut.queries)
                    fut.error = error
                    fut._event.set()
                    n += 1
        return n

    # -- observability --------------------------------------------------
    def stats(self) -> dict:
        with self.lock:
            tenants = {}
            for name, ts in self.tenants.items():
                tenants[name] = {
                    "weight": ts.weight,
                    "queue_depth": len(ts.q),
                    "queued_rows": ts.queued_rows,
                    "submitted": ts.submitted,
                    "completed": ts.completed,
                    "shed": ts.shed,
                    "deadline_misses": ts.deadline_misses,
                    "rate_limited": ts.rate_limited,
                    "p50_ms": _ms(ts.lat.quantile(50)),
                    "p99_ms": _ms(ts.lat.quantile(99)),
                }
            return {
                "target_p99_ms": self.policy.target_p99 * 1e3,
                "degrade_level": self.controller.level,
                "pressure": self.pressure,
                "queue_depth": self._queued_requests,
                "queued_rows": self._queued_rows,
                "rows_per_s": self.rows_per_s or 0.0,
                "shed": self.shed_total,
                "deadline_misses": self.deadline_miss_total,
                "rate_limited": self.rate_limited_total,
                "overshoot_avoided": self.overshoot_avoided,
                "p50_ms": _ms(self.lat.quantile(50)),
                "p99_ms": _ms(self.lat.quantile(99)),
                "tenants": tenants,
            }


def _ms(x: Optional[float]) -> Optional[float]:
    return None if x is None else x * 1e3
