"""Functional state pytrees for the SVFusion index.

Three tiers mirror the paper's architecture (DESIGN.md §2, paper §4.2):

* ``GraphState`` — the in-memory capacity tier (paper: CPU DRAM). Holds
  vectors, the fixed-out-degree KNN graph, the deletion bitset, in-degrees
  and per-vertex versions.
* ``CacheState`` — the bandwidth tier (paper: GPU HBM). Holds M ≪ N hot
  vectors, the slot↔host-id mapping table, clock reference bits, the decayed
  recent-access counters and the adaptive promotion threshold θ.
* ``IndexState.tiered`` — optional disk tier backend (paper: SSD). When
  set, the capacity tier is a host window over disk memmaps
  (``repro.core.tiers.TieredBackend``) and the engine resolves misses via
  the cascading lookup device cache → host window → disk. The backend is a
  *host-side* object: it is registered as static pytree aux data, so jitted
  functional-core transforms see only the array fields and rebuild states
  with ``tiered=None`` — the engine owns re-attaching the backend.

All arrays are fixed-capacity for jit; ``n`` is the high-water mark.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


class GraphState(NamedTuple):
    vectors: jax.Array     # [N_max, D] float32
    nbrs: jax.Array        # [N_max, R] int32, -1 padding
    alive: jax.Array       # [N_max] bool
    e_in: jax.Array        # [N_max] int32 in-degree (structural term of F_lambda)
    version: jax.Array     # [N_max] int32 per-vertex version (cross-tier sync)
    n: jax.Array           # [] int32 high-water mark

    @property
    def capacity(self) -> int:
        return self.vectors.shape[0]

    @property
    def degree(self) -> int:
        return self.nbrs.shape[1]


class CacheState(NamedTuple):
    vectors: jax.Array     # [M, D] float32 cached hot vectors
    slot_hid: jax.Array    # [M] int32 slot -> host id (-1 empty)
    h2d: jax.Array         # [N_max] int32 host id -> slot (-1 = not cached)
    ref: jax.Array         # [M] int8 clock reference bits
    slot_ver: jax.Array    # [M] int32 cached copy's version
    f_recent: jax.Array    # [N_max] float32 decayed access count  F_recent(x, t)
    theta: jax.Array       # [] float32 promotion threshold
    alpha: jax.Array       # [] float32 weight of F_recent
    beta: jax.Array        # [] float32 weight of log(1+E_in)

    @property
    def n_slots(self) -> int:
        return self.vectors.shape[0]


class Stats(NamedTuple):
    accesses: jax.Array    # [] int64-ish counters (int32 fine for benches)
    hits: jax.Array
    misses: jax.Array
    promotions: jax.Array
    evictions: jax.Array
    transfers: jax.Array   # vectors moved host->device
    cpu_computed: jax.Array  # miss accesses resolved on the capacity tier


class IndexState(NamedTuple):
    graph: GraphState
    cache: CacheState
    stats: Stats
    tiered: Optional[Any] = None   # TieredBackend (static aux, see below)


# The tiered backend is a stateful host object (memmaps, locks, threads):
# it must never be traced. Registering IndexState explicitly overrides the
# default NamedTuple flattening and moves ``tiered`` into the treedef so
# jit sees only (graph, cache, stats). Treedef equality is by backend
# identity — one engine, one backend, stable jit caches.
jax.tree_util.register_pytree_node(
    IndexState,
    lambda s: ((s.graph, s.cache, s.stats), s.tiered),
    lambda aux, ch: IndexState(ch[0], ch[1], ch[2], aux))


class SearchParams(NamedTuple):
    k: int = 10
    pool: int = 64          # candidate pool size L >= k
    max_iters: int = 96     # total hop (expansion) budget per query
    decay: float = 0.9      # F_recent sliding-window decay per batch
    max_promote: int = 2048 # transfer batch (paper amortizes over 2048)
    policy: str = "wavp"    # wavp | lru | lfu | lrfu | never | always
    beam: int = 16          # frontier expansions batched per round; the
    #                         executor runs ceil(max_iters/beam) rounds and
    #                         issues ONE device dispatch per round, so the
    #                         tiered path's dispatch count per query is
    #                         ~max_iters/beam instead of max_iters. beam=1
    #                         recovers the classic per-hop greedy order;
    #                         16 is the bench sweet spot (qps AND recall:
    #                         wider rounds trade re-rank adaptivity for
    #                         coverage + dispatch amortization).


def init_stats() -> Stats:
    return Stats(*(jnp.zeros((), jnp.int32) for _ in range(7)))


def init_cache_state(n_max: int, n_slots: int, dim: int,
                     theta: float = 1.0, alpha: float = 1.0,
                     beta: float = 1.0) -> CacheState:
    return CacheState(
        vectors=jnp.zeros((n_slots, dim), jnp.float32),
        slot_hid=jnp.full((n_slots,), -1, jnp.int32),
        h2d=jnp.full((n_max,), -1, jnp.int32),
        ref=jnp.zeros((n_slots,), jnp.int8),
        slot_ver=jnp.zeros((n_slots,), jnp.int32),
        f_recent=jnp.zeros((n_max,), jnp.float32),
        theta=jnp.asarray(theta, jnp.float32),
        alpha=jnp.asarray(alpha, jnp.float32),
        beta=jnp.asarray(beta, jnp.float32),
    )


def init_graph_state(n_max: int, dim: int, degree: int) -> GraphState:
    return GraphState(
        vectors=jnp.zeros((n_max, dim), jnp.float32),
        nbrs=jnp.full((n_max, degree), -1, jnp.int32),
        alive=jnp.zeros((n_max,), bool),
        e_in=jnp.zeros((n_max,), jnp.int32),
        version=jnp.zeros((n_max,), jnp.int32),
        n=jnp.zeros((), jnp.int32),
    )
