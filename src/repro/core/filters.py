"""Filtered & hybrid search: attribute schema, filter specs and their
compiled predicate forms (GRAB-ANNS-style in-scan filtering; redisvl's
tag/numeric field schema is the API shape).

Production vector queries carry metadata predicates — tenant tags,
categories, numeric ranges — and evaluating them *post-hoc* (search,
then drop non-matching results) collapses recall at any real
selectivity. This module gives the executor an **in-dispatch predicate
lane** instead:

* ``AttributeSchema`` — the fixed per-index schema: named tag fields
  (small-domain uints, one uint32 membership bitmask each) and named
  numeric fields (fp32). Attributes live in ``tiers.AttributeStore``
  (host truth + epoch-synced device mirror, the ``quant.PQCodes``
  pattern).
* ``FilterSpec`` — one query's predicate: per-tag-field allowed value
  sets and per-numeric-field ``[lo, hi]`` ranges, ANDed across fields.
  Hashable: the coalescer batches requests by ``key()`` so only
  filter-compatible requests share a dispatch.
* ``CompiledFilter`` — the device-evaluable form: a uint32 bitmask per
  tag field (bit v set = value v allowed; unconstrained = all ones) and
  fp32 bound vectors per numeric field (unconstrained = ∓inf). One
  jitted pass over the attribute mirror yields a per-id boolean mask
  that the executor ANDs into its existing alive/-1 invalid-lane
  masking (``jnp.where(valid, d, +inf)``), so filtered-out candidates
  never enter the pool — the same composition the ``l2_gather`` /
  ``pq_adc`` kernels already honor for id -1.
* ``estimate_selectivity`` — the cheap host-side sample the engine uses
  at admission to route low-selectivity queries to the brute-force ADC
  fallback (``search.search_tiered``): below the threshold a graph walk
  starves (too few passing candidates to sustain a frontier), so one
  ADC scan over the matched id set wins.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

MAX_TAG_DOMAIN = 32   # membership bitmask rides one uint32 per field


@dataclass(frozen=True)
class AttributeSchema:
    """Fixed per-index attribute schema. ``tag_fields`` hold integer
    values in ``[0, tag_domain)`` (a set-membership bitmask must fit a
    uint32); ``num_fields`` hold fp32 scalars."""

    tag_fields: tuple = ()
    num_fields: tuple = ()
    tag_domain: int = MAX_TAG_DOMAIN

    def __post_init__(self):
        object.__setattr__(self, "tag_fields", tuple(self.tag_fields))
        object.__setattr__(self, "num_fields", tuple(self.num_fields))
        names = self.tag_fields + self.num_fields
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate attribute field names: {names}")
        if not 1 <= self.tag_domain <= MAX_TAG_DOMAIN:
            raise ValueError(
                f"tag_domain must be in [1, {MAX_TAG_DOMAIN}] (one uint32 "
                f"membership bitmask per field), got {self.tag_domain}")

    @property
    def n_tags(self) -> int:
        return len(self.tag_fields)

    @property
    def n_nums(self) -> int:
        return len(self.num_fields)

    def coerce(self, attributes, m: int):
        """Normalize one batch's attribute payload to the store's column
        form: ``(tags [m, n_tags] int32, nums [m, n_nums] fp32)``.
        ``attributes`` may be None (schema defaults: tag 0 / num 0.0), a
        ``(tags, nums)`` pair of arrays in schema field order, or a dict
        of per-field columns keyed by field name (missing fields
        default). Tag values are validated against the domain."""
        tags = np.zeros((m, self.n_tags), np.int32)
        nums = np.zeros((m, self.n_nums), np.float32)
        if attributes is None:
            return tags, nums
        if isinstance(attributes, dict):
            for f, col in attributes.items():
                col = np.asarray(col)
                if col.shape != (m,):
                    raise ValueError(
                        f"attribute column {f!r} must have shape ({m},), "
                        f"got {col.shape}")
                if f in self.tag_fields:
                    tags[:, self.tag_fields.index(f)] = col
                elif f in self.num_fields:
                    nums[:, self.num_fields.index(f)] = col
                else:
                    raise ValueError(f"unknown attribute field {f!r} "
                                     f"(schema: {self.tag_fields} + "
                                     f"{self.num_fields})")
        else:
            t, v = attributes
            if t is not None:
                t = np.asarray(t)
                if t.shape != (m, self.n_tags):
                    raise ValueError(f"tags must have shape "
                                     f"({m}, {self.n_tags}), got {t.shape}")
                tags[:] = t
            if v is not None:
                v = np.asarray(v, np.float32)
                if v.shape != (m, self.n_nums):
                    raise ValueError(f"nums must have shape "
                                     f"({m}, {self.n_nums}), got {v.shape}")
                nums[:] = v
        if self.n_tags and ((tags < 0) | (tags >= self.tag_domain)).any():
            raise ValueError(
                f"tag values must be in [0, {self.tag_domain})")
        return tags, nums

    def to_meta(self) -> dict:
        """JSON-serializable form for the durability manifest."""
        return {"tag_fields": list(self.tag_fields),
                "num_fields": list(self.num_fields),
                "tag_domain": int(self.tag_domain)}

    @classmethod
    def from_meta(cls, meta: dict) -> "AttributeSchema":
        return cls(tag_fields=tuple(meta["tag_fields"]),
                   num_fields=tuple(meta["num_fields"]),
                   tag_domain=int(meta["tag_domain"]))


class FilterSpec:
    """One query's metadata predicate: AND across constrained fields.

    ``tags``: field -> iterable of allowed tag values (set membership).
    ``ranges``: field -> (lo, hi) inclusive numeric bounds (None in
    either slot = unbounded on that side).

    Instances are immutable, hashable and order-insensitive: ``key()``
    is the canonical form the coalescing scheduler batches by —
    requests whose specs key equal may share one executor dispatch;
    anything else dispatches separately.
    """

    __slots__ = ("tags", "ranges", "_key")

    def __init__(self, tags: Optional[dict] = None,
                 ranges: Optional[dict] = None):
        t = {}
        for f, vals in (tags or {}).items():
            vs = frozenset(int(v) for v in vals)
            if not vs:
                raise ValueError(
                    f"empty tag set for field {f!r}: an always-false "
                    f"predicate must be expressed by the caller, not an "
                    f"empty set (likely a bug)")
            t[str(f)] = vs
        r = {}
        for f, bounds in (ranges or {}).items():
            lo, hi = bounds
            lo = -np.inf if lo is None else float(lo)
            hi = np.inf if hi is None else float(hi)
            r[str(f)] = (lo, hi)
        object.__setattr__(self, "tags", t)
        object.__setattr__(self, "ranges", r)
        object.__setattr__(self, "_key", (
            tuple(sorted((f, tuple(sorted(v))) for f, v in t.items())),
            tuple(sorted((f, b) for f, b in r.items()))))

    def __setattr__(self, *_):
        raise AttributeError("FilterSpec is immutable")

    def key(self) -> tuple:
        return self._key

    def __hash__(self):
        return hash(self._key)

    def __eq__(self, other):
        return isinstance(other, FilterSpec) and self._key == other._key

    def __repr__(self):
        return f"FilterSpec(tags={dict(self.tags)!r}, " \
               f"ranges={dict(self.ranges)!r})"


class CompiledFilter(NamedTuple):
    """Schema-resolved device-evaluable predicate: one uint32 membership
    bitmask per tag field and fp32 bound vectors per numeric field
    (unconstrained fields compile to all-ones / ∓inf, so evaluation is
    branch-free across specs of any shape)."""

    tag_masks: np.ndarray   # [n_tags] uint32
    num_lo: np.ndarray      # [n_nums] fp32
    num_hi: np.ndarray      # [n_nums] fp32


def compile_filter(spec: FilterSpec, schema: AttributeSchema
                   ) -> CompiledFilter:
    """Resolve a spec against the index schema. Raises on unknown
    fields or out-of-domain tag values."""
    all_ones = np.uint32((1 << schema.tag_domain) - 1
                         if schema.tag_domain < 32 else 0xFFFFFFFF)
    masks = np.full((schema.n_tags,), all_ones, np.uint32)
    for f, vals in spec.tags.items():
        if f not in schema.tag_fields:
            raise ValueError(f"unknown tag field {f!r} "
                             f"(schema tag fields: {schema.tag_fields})")
        if any(v < 0 or v >= schema.tag_domain for v in vals):
            raise ValueError(f"tag values for {f!r} must be in "
                             f"[0, {schema.tag_domain}), got {sorted(vals)}")
        m = 0
        for v in vals:
            m |= 1 << v
        masks[schema.tag_fields.index(f)] = np.uint32(m)
    lo = np.full((schema.n_nums,), -np.inf, np.float32)
    hi = np.full((schema.n_nums,), np.inf, np.float32)
    for f, (l, h) in spec.ranges.items():
        if f not in schema.num_fields:
            raise ValueError(f"unknown numeric field {f!r} "
                             f"(schema numeric fields: {schema.num_fields})")
        i = schema.num_fields.index(f)
        lo[i], hi[i] = np.float32(l), np.float32(h)
    return CompiledFilter(masks, lo, hi)


def host_pass(cf: CompiledFilter, tags: np.ndarray, nums: np.ndarray
              ) -> np.ndarray:
    """Host-truth predicate evaluation: ``tags [m, n_tags]`` /
    ``nums [m, n_nums]`` -> bool [m]. The numpy twin of the device
    evaluation below — bit-identical by construction (pure integer bit
    tests and fp32 compares)."""
    ok = np.ones(len(tags), bool)
    if tags.shape[1]:
        bits = (cf.tag_masks[None, :] >> tags.astype(np.uint32)) & 1
        ok &= (bits != 0).all(axis=1)
    if nums.shape[1]:
        ok &= ((nums >= cf.num_lo) & (nums <= cf.num_hi)).all(axis=1)
    return ok


@jax.jit
def _device_pass(tags_j, nums_j, tag_masks, num_lo, num_hi):
    ok = jnp.ones((tags_j.shape[0],), bool)
    if tags_j.shape[1]:
        bits = jnp.right_shift(tag_masks[None, :],
                               tags_j.astype(jnp.uint32)) & jnp.uint32(1)
        ok &= (bits != 0).all(axis=1)
    if nums_j.shape[1]:
        ok &= ((nums_j >= num_lo) & (nums_j <= num_hi)).all(axis=1)
    return ok


def device_pass_mask(attrs, cf: CompiledFilter):
    """Per-id predicate mask evaluated ON DEVICE against the attribute
    store's epoch-synced mirror: bool [capacity] device array the
    executor ANDs with ``alive`` before the usual
    ``where(valid, d, +inf)`` masking. One tiny jitted dispatch per
    search batch; the fused round loop then just gathers from it."""
    tags_j, nums_j = attrs.synced()
    return _device_pass(tags_j, nums_j, jnp.asarray(cf.tag_masks),
                        jnp.asarray(cf.num_lo), jnp.asarray(cf.num_hi))


def estimate_selectivity(cf: CompiledFilter, attrs, alive, n: int,
                         sample: int = 1024, seed: int = 0) -> float:
    """Cheap host-side selectivity estimate at admission: the passing
    fraction of a uniform sample of alive ids (host truth columns; no
    device round-trip). Deterministic in ``seed``. Returns 1.0 for an
    empty index (nothing to route on)."""
    n = int(n)
    if n <= 0:
        return 1.0
    if n <= sample:
        ids = np.arange(n)
    else:
        ids = np.random.default_rng(seed).integers(0, n, sample)
    live = np.asarray(alive[:n])[ids] if np.ndim(alive) else None
    ok = host_pass(cf, attrs.tags[ids], attrs.nums[ids])
    if live is not None:
        k = int(live.sum())
        if k == 0:
            return 1.0
        return float((ok & live).sum() / k)
    return float(ok.mean())
