"""Three-tier store (paper §4.2): device cache / host DRAM / disk.

The disk tier holds vectors + graph rows in the same layout as the host
tier via ``np.memmap``; a hash-directory tracks residency and cold vectors
are demoted by ascending F_λ when the host tier saturates — the SAME
per-vector F_λ that drives device-cache promotion in ``cache.apply_wavp``
orders host-window demotion here (paper §4.3, last paragraph). Async
prefetch uses a background thread (the paper's cascading-lookup pipeline):
the engine enqueues predicted-hot neighbor frontiers so disk reads overlap
with device compute (the multi-stream analogue, paper §4.4).

Thread-safety: ``fetch``/``peek``/``write`` serialize on one reentrant
lock; residency bookkeeping (``loc``/``slot_id``/host arrays) is only
ever touched under it. The prefetcher performs its disk reads OUTSIDE
the lock (so background IO genuinely overlaps foreground traffic) and
re-validates residency + a store write-epoch before installing, dropping
the batch if a write raced it. Its queue is bounded: under overload new
predictions are dropped, not accumulated stale. Free slots are handed
out by a monotone cursor (slots are never returned), so promotion is
O(batch) instead of a per-miss ``np.where`` scan.
"""
from __future__ import annotations

import os
import queue
import threading
from typing import Optional

import numpy as np


class DiskTier:
    """Memory-mapped vector + graph store."""

    def __init__(self, path: str, capacity: int, dim: int, degree: int,
                 create=True):
        os.makedirs(path, exist_ok=True)
        mode = "w+" if create else "r+"
        self.vec = np.memmap(os.path.join(path, "vectors.npy"), np.float32,
                             mode, shape=(capacity, dim))
        self.nbr = np.memmap(os.path.join(path, "nbrs.npy"), np.int32,
                             mode, shape=(capacity, degree))
        if create:
            self.nbr[:] = -1
        self.capacity, self.dim, self.degree = capacity, dim, degree

    def write(self, ids, vectors=None, nbrs=None):
        if vectors is not None:
            self.vec[ids] = vectors
        if nbrs is not None:
            self.nbr[ids] = nbrs

    def read(self, ids):
        return np.asarray(self.vec[ids]), np.asarray(self.nbr[ids])

    def flush(self):
        """Durable flush: ``mmap.flush`` writes dirty pages back but does
        not guarantee they reach stable storage on all platforms — follow
        with an ``os.fsync`` on each backing file (an O_RDONLY fd is
        enough to fsync on POSIX)."""
        for mm in (self.vec, self.nbr):
            mm.flush()
            fd = os.open(mm.filename, os.O_RDONLY)
            try:
                os.fsync(fd)
            finally:
                os.close(fd)


class TieredStore:
    """Host window over a disk-resident dataset.

    Residency directory: ``loc[id] = slot`` into the host window or -1.
    Demotion policy: lowest-F_λ residents leave the host window first.
    """

    def __init__(self, disk: DiskTier, host_slots: int):
        self.disk = disk
        self.host_slots = host_slots
        self.host_vec = np.zeros((host_slots, disk.dim), np.float32)
        self.host_nbr = np.full((host_slots, disk.degree), -1, np.int32)
        self.loc = np.full((disk.capacity,), -1, np.int64)      # id -> slot
        self.slot_id = np.full((host_slots,), -1, np.int64)     # slot -> id
        self.hits = 0
        self.misses = 0
        self.demotions = 0
        self.prefetched = 0
        self.prefetch_dropped = 0
        self._lock = threading.RLock()
        self._free_cursor = 0           # slots are allotted once, never freed
        self._write_epoch = 0           # bumped by write(); guards installs
        self._prefetch_q: queue.Queue = queue.Queue(maxsize=64)
        self._stop = threading.Event()
        self._th: Optional[threading.Thread] = None

    # -- residency ------------------------------------------------------
    def fetch(self, ids: np.ndarray, f_lambda: Optional[np.ndarray] = None,
              *, count: bool = True):
        """Read rows, promoting misses into the host window (demote lowest
        F_λ residents when full). Returns (vectors, nbr_rows) copies."""
        ids = np.asarray(ids)
        with self._lock:
            out_v = np.empty((len(ids), self.disk.dim), np.float32)
            out_n = np.empty((len(ids), self.disk.degree), np.int32)
            slots = self.loc[ids]
            hit = slots >= 0
            if count:
                self.hits += int(hit.sum())
                self.misses += int((~hit).sum())
            out_v[hit] = self.host_vec[slots[hit]]
            out_n[hit] = self.host_nbr[slots[hit]]
            miss_ids = ids[~hit]
            if miss_ids.size:
                dv, dn = self.disk.read(miss_ids)
                out_v[~hit] = dv
                out_n[~hit] = dn
                self._promote(miss_ids, dv, dn, f_lambda)
            return out_v, out_n

    def peek(self, ids: np.ndarray):
        """Read rows through the window overlay WITHOUT promotion or
        counter updates (maintenance scans must not thrash the window)."""
        ids = np.asarray(ids)
        with self._lock:
            out_v = np.empty((len(ids), self.disk.dim), np.float32)
            out_n = np.empty((len(ids), self.disk.degree), np.int32)
            slots = self.loc[ids]
            hit = slots >= 0
            out_v[hit] = self.host_vec[slots[hit]]
            out_n[hit] = self.host_nbr[slots[hit]]
            if (~hit).any():
                dv, dn = self.disk.read(ids[~hit])
                out_v[~hit] = dv
                out_n[~hit] = dn
            return out_v, out_n

    def fetch_rows(self, ids: np.ndarray,
                   f_lambda: Optional[np.ndarray] = None, *,
                   count: bool = True):
        """Adjacency-only ``fetch`` (the speculative pipeline's delta-fetch
        API): window hits skip the vector copy entirely; misses read both
        halves from disk — the promotion install needs the vectors anyway
        — and promote exactly like ``fetch``. Returns nbr rows, a copy."""
        ids = np.asarray(ids)
        with self._lock:
            out_n = np.empty((len(ids), self.disk.degree), np.int32)
            slots = self.loc[ids]
            hit = slots >= 0
            if count:
                self.hits += int(hit.sum())
                self.misses += int((~hit).sum())
            out_n[hit] = self.host_nbr[slots[hit]]
            miss_ids = ids[~hit]
            if miss_ids.size:
                dv, dn = self.disk.read(miss_ids)
                out_n[~hit] = dn
                self._promote(miss_ids, dv, dn, f_lambda)
            return out_n

    @property
    def write_epoch(self) -> int:
        """Monotone write counter (reading an int is atomic under the
        GIL): speculative staging snapshots it and flushes its memos when
        it moves — a staged row must never outlive a concurrent write."""
        return self._write_epoch

    def peek_rows(self, ids: np.ndarray):
        """Adjacency-only ``peek``: rows through the window overlay
        without promotion, counters, or the vector copy. The MVCC
        snapshot and the prefetch predictor read topology at scale —
        copying D floats per id alongside would dominate their cost."""
        ids = np.asarray(ids)
        with self._lock:
            out_n = np.empty((len(ids), self.disk.degree), np.int32)
            slots = self.loc[ids]
            hit = slots >= 0
            out_n[hit] = self.host_nbr[slots[hit]]
            if (~hit).any():
                out_n[~hit] = np.asarray(self.disk.nbr[ids[~hit]])
            return out_n

    def write(self, ids, vectors=None, nbrs=None):
        """Write-through update: disk always, host window where resident
        (keeps the overlay coherent without dirty tracking; demotion
        write-back then never loses updates)."""
        ids = np.asarray(ids)
        with self._lock:
            self._write_epoch += 1
            self.disk.write(ids, vectors, nbrs)
            slots = self.loc[ids]
            res = slots >= 0
            if res.any():
                if vectors is not None:
                    self.host_vec[slots[res]] = np.asarray(vectors)[res]
                if nbrs is not None:
                    self.host_nbr[slots[res]] = np.asarray(nbrs)[res]

    def _promote(self, ids, vecs, nbrs, f_lambda):
        """Install missed rows (already read) into the window. Caller holds
        the lock; ids may contain duplicates."""
        uniq, first = np.unique(np.asarray(ids), return_index=True)
        fresh = self.loc[uniq] < 0
        uniq, first = uniq[fresh], first[fresh]
        if uniq.size > self.host_slots:
            # miss batch alone exceeds the window: admit the hottest subset
            if f_lambda is not None:
                keep = np.argsort(
                    -np.asarray(f_lambda, np.float64)[uniq])[:self.host_slots]
            else:
                keep = np.arange(self.host_slots)
            uniq, first = uniq[keep], first[keep]
        m = uniq.size
        if not m:
            return
        slots = np.empty((m,), np.int64)
        take = min(m, self.host_slots - self._free_cursor)
        if take > 0:
            slots[:take] = np.arange(self._free_cursor,
                                     self._free_cursor + take)
            self._free_cursor += take
        spill = m - take
        if spill > 0:
            # demote the lowest-F_λ residents; slots allotted above are
            # still unpublished (slot_id == -1) and must not be victims
            res_ids = self.slot_id
            if f_lambda is not None:
                key = np.asarray(f_lambda,
                                 np.float64)[np.clip(res_ids, 0, None)].copy()
            else:
                key = np.random.random(self.host_slots)
            key[res_ids < 0] = np.inf
            victims = np.argpartition(key, spill - 1)[:spill]
            old = res_ids[victims]
            self.disk.write(old, self.host_vec[victims],
                            self.host_nbr[victims])
            self.loc[old] = -1
            self.demotions += int(spill)
            slots[take:] = victims
        self.host_vec[slots] = vecs[first]
        self.host_nbr[slots] = nbrs[first]
        self.slot_id[slots] = uniq
        self.loc[uniq] = slots

    # -- async prefetch ---------------------------------------------------
    def start_prefetcher(self):
        if self._stop.is_set():     # stop() is terminal (close in flight)
            return

        def work():
            while not self._stop.is_set():
                try:
                    ids, f_lam = self._prefetch_q.get(timeout=0.05)
                except queue.Empty:
                    continue
                self._prefetch_one(np.unique(ids), f_lam)
        self._th = threading.Thread(target=work, daemon=True)
        self._th.start()

    def _prefetch_one(self, ids, f_lam):
        """One overlapped prefetch: residency probe under the lock, disk
        read OUTSIDE it, install re-validated against the write epoch."""
        with self._lock:
            miss = ids[self.loc[ids] < 0]
            epoch = self._write_epoch
        if not miss.size:
            return
        dv, dn = self.disk.read(miss)          # overlaps foreground work
        with self._lock:
            if self._write_epoch != epoch:
                self.prefetch_dropped += len(miss)
                return                         # a write raced the read
            still = self.loc[miss] < 0
            if still.any():
                self._promote(miss[still], dv[still], dn[still], f_lam)
                self.prefetched += int(still.sum())

    def prefetch(self, ids, f_lambda: Optional[np.ndarray] = None):
        if self._stop.is_set():
            return                  # shutdown in flight: never enqueue work
            #                         the closing disk tier would receive
        try:
            self._prefetch_q.put_nowait((np.asarray(ids), f_lambda))
        except queue.Full:
            self.prefetch_dropped += len(ids)  # overload: drop, don't lag

    def stop(self):
        """Terminal shutdown: the worker MUST be joined before the caller
        closes/flushes the disk tier, or an in-flight ``_prefetch_one``
        can still be mid-write when the memmaps go away. ``prefetch`` and
        ``start_prefetcher`` are no-ops afterwards."""
        self._stop.set()
        th = self._th
        if th is not None:
            th.join(timeout=10.0)
            if th.is_alive():       # pragma: no cover - worker is bounded
                raise RuntimeError("prefetcher failed to stop; refusing to "
                                   "close the disk tier under it")
            self._th = None

    @property
    def resident(self) -> int:
        return int((self.slot_id >= 0).sum())

    @property
    def miss_rate(self):
        tot = self.hits + self.misses
        return self.misses / tot if tot else 0.0


class AttributeStore:
    """Per-id fixed-schema attribute columns for filtered search
    (``core.filters``): tag fields (small-domain uints, one int32 column
    each) and numeric fields (fp32 columns) over the whole id space.

    Follows the ``quant.PQCodes`` directory pattern exactly: host-truth
    numpy columns written through by ``update.insert_tiered``, a device
    mirror synced lazily per search batch (``synced`` folds all dirty
    blocks in ONE scatter per column), and a locked ``snapshot`` for the
    durability layer. Attributes are immutable per id (like vectors), so
    consolidation/merge never rewrites them."""

    def __init__(self, schema, capacity: int, tags=None, nums=None):
        import jax.numpy as jnp
        self.schema = schema
        self.capacity = int(capacity)
        self.tags = np.zeros((self.capacity, schema.n_tags), np.int32)
        self.nums = np.zeros((self.capacity, schema.n_nums), np.float32)
        if tags is not None:
            self.tags[:len(tags)] = np.asarray(tags, np.int32)
        if nums is not None:
            self.nums[:len(nums)] = np.asarray(nums, np.float32)
        self._tags_j = jnp.asarray(self.tags)
        self._nums_j = jnp.asarray(self.nums)
        self._dirty: list = []
        self._lock = threading.Lock()
        self.written = 0    # ids written through (observability)

    def write(self, ids, tags, nums):
        """Write-through attribute install for freshly inserted ids:
        host truth now, device mirror folded at the next ``synced``."""
        ids = np.asarray(ids, np.int64)
        if not len(ids):
            return
        with self._lock:
            self.tags[ids] = np.asarray(tags, np.int32)
            self.nums[ids] = np.asarray(nums, np.float32)
            self._dirty.append(ids.copy())
            self.written += len(ids)

    def synced(self):
        """Device mirror columns with every host write folded in — ONE
        ``.at[ids].set`` scatter per column regardless of how many write
        batches accumulated (the PQCodes sync idiom)."""
        import jax.numpy as jnp
        with self._lock:
            if self._dirty:
                ids = np.concatenate(self._dirty)
                self._dirty = []
                idx = jnp.asarray(ids)
                self._tags_j = self._tags_j.at[idx].set(
                    jnp.asarray(self.tags[ids]))
                self._nums_j = self._nums_j.at[idx].set(
                    jnp.asarray(self.nums[ids]))
            return self._tags_j, self._nums_j

    def snapshot(self, n: int):
        """Consistent host-truth copy of the live prefix (the durability
        snapshot path; taken under the write lock)."""
        with self._lock:
            return self.tags[:n].copy(), self.nums[:n].copy()

    def attr_bytes(self, n: int) -> int:
        return int(n) * (self.schema.n_tags * 4 + self.schema.n_nums * 4)


class TieredBackend:
    """Disk-backed capacity tier for ``SVFusionEngine``.

    Bundles the TieredStore with the host-resident graph metadata the
    paper keeps in DRAM directories (alive bitset, in-degrees, versions,
    high-water mark) — a few bytes per id, vs. D·4 bytes per vector, so
    the directory fits in memory even when vectors/rows do not.
    Mutations happen under the engine's update stream; searches read the
    arrays lock-free (numpy loads of a published array are atomic enough
    for the approximate structures involved).
    """

    def __init__(self, store: TieredStore, n: int):
        cap = store.disk.capacity
        self.store = store
        self.n = int(n)
        self.alive = np.zeros((cap,), bool)
        self.e_in = np.zeros((cap,), np.int32)
        self.version = np.zeros((cap,), np.int32)
        self.pq = None      # quant.PQCodes lane (attach_pq); codes are a
        #                     directory-style array: unconditionally
        #                     host+device resident, written through by
        #                     update.insert_tiered's incremental encode
        self.topo = None    # cache.TopoCache row-slot lane (attach_topo):
        #                     device-resident adjacency rows for the fused
        #                     multi-round executor, F_λ-ordered residency,
        #                     epoch-fenced against store writes
        self.wal = None     # wal.WriteAheadLog: when attached, the update
        #                     path logs each op BEFORE mutating the store
        #                     (recovery replays the log over the last
        #                     published snapshot); owned by the engine
        self.attrs = None   # AttributeStore (attach_attrs): per-id tag /
        #                     numeric columns for the filtered-search
        #                     predicate lane; host truth + epoch-synced
        #                     device mirror, written through by
        #                     update.insert_tiered, snapshot-persisted

    def attach_topo(self, topo) -> None:
        """Attach the device-resident topology row cache
        (``cache.TopoCache``). Its id->slot directory spans the whole id
        space like alive/e_in; the fused executor installs rows on demand
        and validates against the store's write epoch per host re-entry."""
        if topo.capacity != self.capacity:
            raise ValueError(
                f"topo cache spans {topo.capacity} ids, disk capacity is "
                f"{self.capacity}")
        if topo.degree != self.degree:
            raise ValueError(
                f"topo cache rows are degree {topo.degree}, graph degree "
                f"is {self.degree}")
        self.topo = topo

    def attach_pq(self, pq) -> None:
        """Attach the PQ code lane (``quant.PQCodes``). The lane's code
        array spans the whole id space like alive/e_in; inserts encode
        incrementally into it (write-through), searches read the epoch-
        synced device mirror."""
        if pq.codes.shape[0] != self.capacity:
            raise ValueError(
                f"pq codes span {pq.codes.shape[0]} ids, disk capacity is "
                f"{self.capacity}")
        self.pq = pq

    def attach_attrs(self, attrs) -> None:
        """Attach the per-id attribute lane (``AttributeStore``). The
        columns span the whole id space like alive/e_in; inserts write
        through incrementally, filtered searches read the epoch-synced
        device mirror."""
        if attrs.capacity != self.capacity:
            raise ValueError(
                f"attribute store spans {attrs.capacity} ids, disk "
                f"capacity is {self.capacity}")
        self.attrs = attrs

    @property
    def capacity(self) -> int:
        return self.store.disk.capacity

    @property
    def dim(self) -> int:
        return self.store.disk.dim

    @property
    def degree(self) -> int:
        return self.store.disk.degree

    def deleted_fraction(self) -> float:
        n = max(self.n, 1)
        return float((~self.alive[:self.n]).sum()) / n

    def tier_counts(self) -> dict:
        s = self.store
        out = {"host_hits": s.hits, "disk_reads": s.misses,
               "host_miss_rate": s.miss_rate, "demotions": s.demotions,
               "prefetched": s.prefetched,
               "prefetch_dropped": s.prefetch_dropped,
               "host_resident": s.resident}
        if self.pq is not None:
            out["pq_encoded_incremental"] = self.pq.encoded
        if self.attrs is not None:
            out["attrs_written"] = self.attrs.written
        if self.topo is not None:
            t = self.topo
            out.update(topo_hits=t.hits, topo_misses=t.misses,
                       topo_hit_rate=t.hit_rate, topo_installs=t.installs,
                       topo_evictions=t.evictions, topo_flushes=t.flushes,
                       topo_resident=t.resident)
        return out

    def bytes_per_tier(self) -> dict:
        """Allocated byte footprint of each tier's payload arrays (the
        device exact-vector cache belongs to HostPlacement; the engine
        merges it in). ``device_codes`` counts the PQ lane's resident
        codes over the live id space [0, n) — the allocated [capacity, m]
        array is sized for growth headroom, like the disk memmaps."""
        s = self.store
        out = {
            "host_window": int(s.host_vec.nbytes + s.host_nbr.nbytes),
            "disk": int(self.capacity
                        * (self.dim * 4 + self.degree * 4)),
            "device_codes": (self.pq.code_bytes(self.n)
                             if self.pq is not None else 0),
            # topology row slots + id->slot directory (the fused
            # executor's device-resident adjacency lane)
            "device_topo_rows": (self.topo.row_bytes
                                 if self.topo is not None else 0),
            # attribute columns are host+device resident like PQ codes;
            # a few bytes/id, so they never threaten the vector budget
            "host_attrs": (self.attrs.attr_bytes(self.n)
                           if self.attrs is not None else 0),
        }
        return out

    def close(self):
        # join the prefetcher BEFORE flushing/abandoning the memmaps: a
        # worker mid-``_prefetch_one`` must never outlive the disk tier
        self.store.stop()
        self.store.disk.flush()


def probe_fetch_latency(backend: TieredBackend, *, batches: int = 4,
                        batch: int = 64, seed: int = 0) -> float:
    """Measure the per-row delta-fetch latency (microseconds) of the disk
    tier with a short random-read probe. This is the quantity the
    ``spec_rank`` default hinges on (ROADMAP): exact host re-ranking of
    the frontier prediction (``"dist"``) costs ~ms of host compute per
    round and only pays for itself when mispredicted delta fetches are
    genuinely IO-bound — true on a real SSD (~100 µs/row), false on a
    page-cache-backed "disk" (~1 µs/row). Reads go straight to the memmap
    (no window promotion, no counter pollution); the probe runs once at
    engine startup.

    Two cache effects would otherwise defeat the measurement: the probe
    runs right after the index build wrote every row, so the pages are
    warm AND dirty (flush first — DONTNEED cannot free dirty pages, then
    evict each probed id's page range with ``posix_fadvise(DONTNEED)``);
    and mispredict delta fetches are *scattered* ids, so the probe reads
    scattered single rows — a contiguous span would amortize onto a
    couple of page faults plus readahead and measure ~sequential
    latency. On tmpfs/ramdisk the advise is a no-op and the probe
    correctly measures memory speed."""
    import time
    rng = np.random.default_rng(seed)
    disk = backend.store.disk
    n = max(backend.n, 1)
    page = 4096
    ids = rng.integers(0, n, batches * batch)     # scattered, like misses
    fds = []
    try:
        # a delta fetch reads BOTH memmaps (vectors + adjacency): evict
        # each probed id's page range in each file, or the warm half
        # understates the cold cost by up to 2x
        for mm, row_bytes in ((disk.vec, disk.dim * 4),
                              (disk.nbr, disk.degree * 4)):
            try:
                fd = os.open(mm.filename, os.O_RDONLY)
            except (OSError, TypeError, AttributeError):
                continue
            fds.append(fd)
            if hasattr(os, "posix_fadvise"):
                mm.flush()      # dirty pages are not evictable
                for i in ids:   # evict BEFORE timing starts
                    off = int(i) * row_bytes // page * page
                    os.posix_fadvise(fd, off, row_bytes + page,
                                     os.POSIX_FADV_DONTNEED)
        t0 = time.perf_counter()
        for s in range(0, len(ids), batch):
            disk.read(ids[s:s + batch])
        dt = time.perf_counter() - t0
    finally:
        for fd in fds:
            os.close(fd)
    return dt / max(len(ids), 1) * 1e6
