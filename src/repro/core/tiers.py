"""Three-tier store (paper §4.2): device cache / host DRAM / disk.

The disk tier holds vectors + graph rows in the same layout as the host
tier via ``np.memmap``; a hash-directory tracks residency and cold vectors
are demoted by ascending F_λ when the host tier saturates. Async prefetch
uses a background thread (the paper's cascading-lookup pipeline).
"""
from __future__ import annotations

import os
import queue
import threading
from typing import Optional

import numpy as np


class DiskTier:
    """Memory-mapped vector + graph store."""

    def __init__(self, path: str, capacity: int, dim: int, degree: int,
                 create=True):
        os.makedirs(path, exist_ok=True)
        mode = "w+" if create else "r+"
        self.vec = np.memmap(os.path.join(path, "vectors.npy"), np.float32,
                             mode, shape=(capacity, dim))
        self.nbr = np.memmap(os.path.join(path, "nbrs.npy"), np.int32,
                             mode, shape=(capacity, degree))
        if create:
            self.nbr[:] = -1
        self.capacity, self.dim, self.degree = capacity, dim, degree

    def write(self, ids, vectors, nbrs=None):
        self.vec[ids] = vectors
        if nbrs is not None:
            self.nbr[ids] = nbrs

    def read(self, ids):
        return np.asarray(self.vec[ids]), np.asarray(self.nbr[ids])

    def flush(self):
        self.vec.flush()
        self.nbr.flush()


class TieredStore:
    """Host window over a disk-resident dataset.

    Residency directory: ``loc[id] = slot`` into the host window or -1.
    Demotion policy: lowest-F_λ rows leave the host window first (paper
    §4.3 last paragraph).
    """

    def __init__(self, disk: DiskTier, host_slots: int):
        self.disk = disk
        self.host_slots = host_slots
        self.host_vec = np.zeros((host_slots, disk.dim), np.float32)
        self.host_nbr = np.full((host_slots, disk.degree), -1, np.int32)
        self.loc = np.full((disk.capacity,), -1, np.int64)      # id -> slot
        self.slot_id = np.full((host_slots,), -1, np.int64)     # slot -> id
        self.hits = 0
        self.misses = 0
        self._prefetch_q: queue.Queue = queue.Queue()
        self._stop = threading.Event()
        self._th: Optional[threading.Thread] = None

    # -- residency ------------------------------------------------------
    def fetch(self, ids: np.ndarray, f_lambda: Optional[np.ndarray] = None):
        """Read rows, promoting misses into the host window (demote lowest
        F_λ residents when full)."""
        ids = np.asarray(ids)
        out_v = np.empty((len(ids), self.disk.dim), np.float32)
        out_n = np.empty((len(ids), self.disk.degree), np.int32)
        slots = self.loc[ids]
        hit = slots >= 0
        self.hits += int(hit.sum())
        self.misses += int((~hit).sum())
        out_v[hit] = self.host_vec[slots[hit]]
        out_n[hit] = self.host_nbr[slots[hit]]
        miss_ids = ids[~hit]
        if miss_ids.size:
            dv, dn = self.disk.read(miss_ids)
            out_v[~hit] = dv
            out_n[~hit] = dn
            self._promote(miss_ids, dv, dn, f_lambda)
        return out_v, out_n

    def _promote(self, ids, vecs, nbrs, f_lambda):
        for i, vid in enumerate(ids):
            if self.loc[vid] >= 0:
                continue
            empty = np.where(self.slot_id < 0)[0]
            if empty.size:
                s = empty[0]
            else:
                # demote the resident with lowest F_λ
                if f_lambda is not None:
                    s = int(np.argmin(f_lambda[self.slot_id]))
                else:
                    s = int(np.random.randint(self.host_slots))
                old = self.slot_id[s]
                self.disk.write([old], self.host_vec[s:s + 1],
                                self.host_nbr[s:s + 1])
                self.loc[old] = -1
            self.host_vec[s] = vecs[i]
            self.host_nbr[s] = nbrs[i]
            self.slot_id[s] = vid
            self.loc[vid] = s

    # -- async prefetch ---------------------------------------------------
    def start_prefetcher(self):
        def work():
            while not self._stop.is_set():
                try:
                    ids = self._prefetch_q.get(timeout=0.05)
                except queue.Empty:
                    continue
                self.fetch(ids)
        self._th = threading.Thread(target=work, daemon=True)
        self._th.start()

    def prefetch(self, ids):
        self._prefetch_q.put(np.asarray(ids))

    def stop(self):
        self._stop.set()
        if self._th:
            self._th.join(timeout=2.0)

    @property
    def miss_rate(self):
        tot = self.hits + self.misses
        return self.misses / tot if tot else 0.0
