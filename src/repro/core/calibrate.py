"""Cost-model calibration for the WAVP gain function (paper §4.3).

gain(x) = λ_x · (T_CPU − T_GPU) − T_transfer, θ = T_transfer/(T_CPU − T_GPU).

Two sources:
* ``v5e_constants()`` — analytical TPU v5e numbers used by the dry-run
  roofline and the production θ default (ICI plays PCIe's role, DESIGN §2).
* ``measure()`` — wall-clock microbenchmarks on the current runtime, used
  by CPU-side benchmarks so θ reflects the machine the benches run on.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class CostModel:
    t_fast: float      # per-vector distance time on the bandwidth tier (s)
    t_slow: float      # per-vector distance time on the capacity tier (s)
    t_transfer: float  # per-vector transfer cost, amortized over batch (s)
    batch: int = 2048  # paper's transfer amortization batch

    @property
    def theta(self) -> float:
        denom = max(self.t_slow - self.t_fast, 1e-12)
        return self.t_transfer / denom


def v5e_constants(dim: int, dtype_bytes: int = 4) -> CostModel:
    """Analytical v5e: fast tier = local HBM (819 GB/s), slow tier = remote
    shard over ICI (~50 GB/s effective per chip) + compute-at-owner,
    transfer = ICI bulk move amortized over 2048-vector batches."""
    bytes_per_vec = dim * dtype_bytes
    t_fast = bytes_per_vec / 819e9
    t_slow = bytes_per_vec / 50e9          # dominated by ICI result/row move
    t_transfer = bytes_per_vec / 50e9      # same wire, bulk-amortized
    return CostModel(t_fast, t_slow, t_transfer)


def measure(dim: int = 64, n: int = 4096, reps: int = 5) -> CostModel:
    """Microbenchmark the actual runtime (CPU container): distance compute
    from a small 'cache' table vs the big table, plus host->device copy."""
    key = jax.random.PRNGKey(0)
    small = jax.random.normal(key, (n, dim))
    big = jax.random.normal(key, (16 * n, dim))
    q = jax.random.normal(key, (dim,))
    idx = jax.random.randint(key, (n,), 0, n)

    @jax.jit
    def dist(table, ids, q):
        x = table[ids]
        return jnp.sum((x - q) ** 2, axis=1)

    def bench(fn):
        fn().block_until_ready()
        t0 = time.perf_counter()
        for _ in range(reps):
            fn().block_until_ready()
        return (time.perf_counter() - t0) / (reps * n)

    t_fast = bench(lambda: dist(small, idx, q))
    t_slow = bench(lambda: dist(big, idx * 16, q))
    host = np.asarray(small)

    def xfer():
        return jax.device_put(host).block_until_ready()
    xfer()
    t0 = time.perf_counter()
    for _ in range(reps):
        xfer()
    t_transfer = (time.perf_counter() - t0) / (reps * n)
    return CostModel(t_fast, max(t_slow, t_fast * 1.01), t_transfer)
