"""Exact sequential clock-sweep eviction (paper Algorithm 2, lines 3-11).

NumPy reference used by tests as the semantics oracle for the vectorized
batched clock in cache.py. The paper's procedure: advance the hand; a slot
with ref=1 gets its bit cleared (second chance); a slot with ref=0 whose
predicted frequency equals the current minimum among ref=0 slots is the
victim.
"""
from __future__ import annotations

import numpy as np


class SequentialClock:
    def __init__(self, n_slots: int):
        self.n = n_slots
        self.hand = 0
        self.ref = np.zeros(n_slots, np.int8)
        self.occupant = np.full(n_slots, -1, np.int64)

    def access(self, slot: int):
        self.ref[slot] = 1

    def admit(self, new_id: int, f_lambda: np.ndarray) -> int:
        """Evict-and-place per Algorithm 2. f_lambda indexed by host id.
        Returns the slot used."""
        empty = np.where(self.occupant < 0)[0]
        if empty.size:
            s = int(empty[0])
            self.occupant[s] = new_id
            self.ref[s] = 1
            return s
        # min F_lambda among ref==0 occupants (recomputed as bits clear)
        for _ in range(2 * self.n + 1):
            zero = self.ref == 0
            if zero.any():
                fmin = f_lambda[self.occupant[zero]].min()
            else:
                fmin = None
            s = self.hand
            if self.ref[s] == 0 and fmin is not None \
                    and f_lambda[self.occupant[s]] == fmin:
                self.occupant[s] = new_id
                self.ref[s] = 1
                self.hand = (s + 1) % self.n
                return s
            if self.ref[s] == 1:
                self.ref[s] = 0
            self.hand = (self.hand + 1) % self.n
        raise RuntimeError("clock failed to find a victim")

    def victims_for(self, new_ids, f_lambda):
        """Admit a batch; returns evicted host ids (order of admission)."""
        evicted = []
        for nid in new_ids:
            s_prev = None
            full = (self.occupant >= 0).all()
            old = self.occupant[self.hand] if full else -1
            s = self.admit(nid, f_lambda)
            evicted.append(int(old) if full else -1)
        return evicted
