"""Baseline ANNS indexes for the paper's comparison set (§6.1), NumPy
implementations at bench scale.

* ``HNSW`` — hierarchical navigable small world (Malkov & Yashunin), the
  paper's CPU baseline. M=48, ef=128 defaults as in the paper. Deletions
  are mark-only (no repair) — reproducing the paper's observation that
  HNSW recall decays under churn.
* ``Vamana`` — DiskANN/FreshDiskANN-style graph with RobustPrune
  (α=1.2, R=64, L=128 per the paper's FreshDiskANN config) + lazy delete +
  consolidation at a deletion threshold.
* ``CagraStatic`` — static GPU-style index: full rebuild on update batches
  (amortized), search always on the "device" graph; models the
  GPU-baselines' update cost.
* ``UVMEmulated`` — SVFusion machinery with promote-every-miss placement
  (the unified-virtual-memory behavior of CAGRA/GGNN beyond device memory).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

import numpy as np


def _l2(a, b):
    return ((a - b) ** 2).sum(-1)


class HNSW:
    def __init__(self, dim, M=16, ef_construction=128, ef_search=128,
                 seed=0, max_elements=1 << 20):
        self.dim, self.M, self.efc, self.efs = dim, M, ef_construction, ef_search
        self.ml = 1.0 / math.log(M)
        self.rng = np.random.default_rng(seed)
        self.vecs = np.zeros((0, dim), np.float32)
        self.levels: list[int] = []
        self.links: list[dict[int, list[int]]] = []   # per node: level->nbrs
        self.alive: list[bool] = []
        self.entry = -1
        self.max_level = -1

    # -- internals ------------------------------------------------------
    def _search_layer(self, q, entry, level, ef):
        visited = {entry}
        d0 = float(_l2(self.vecs[entry], q))
        cand = [(d0, entry)]
        best = [(d0, entry)]
        while cand:
            cand.sort()
            d, u = cand.pop(0)
            if d > max(b[0] for b in best) and len(best) >= ef:
                break
            nbrs = [v for v in self.links[u].get(level, []) if v not in visited]
            visited.update(nbrs)
            if not nbrs:
                continue
            ds = _l2(self.vecs[nbrs], q)
            for dv, v in zip(ds, nbrs):
                worst = max(b[0] for b in best)
                if len(best) < ef or dv < worst:
                    best.append((float(dv), v))
                    cand.append((float(dv), v))
                    if len(best) > ef:
                        best.sort()
                        best = best[:ef]
        best.sort()
        return best

    def _select(self, cands, M):
        return [v for _, v in sorted(cands)[:M]]

    # -- api -------------------------------------------------------------
    def insert(self, vectors):
        ids = []
        for vec in np.asarray(vectors, np.float32):
            nid = len(self.levels)
            self.vecs = np.vstack([self.vecs, vec[None]])
            lvl = int(-math.log(self.rng.random() + 1e-12) * self.ml)
            self.levels.append(lvl)
            self.links.append({})
            self.alive.append(True)
            if self.entry < 0:
                self.entry, self.max_level = nid, lvl
                ids.append(nid)
                continue
            cur = self.entry
            for level in range(self.max_level, lvl, -1):
                cur = self._search_layer(vec, cur, level, 1)[0][1]
            for level in range(min(lvl, self.max_level), -1, -1):
                cands = self._search_layer(vec, cur, level, self.efc)
                M = self.M * 2 if level == 0 else self.M
                sel = self._select(cands, M)
                self.links[nid][level] = list(sel)
                for v in sel:
                    row = self.links[v].setdefault(level, [])
                    row.append(nid)
                    if len(row) > M:
                        ds = _l2(self.vecs[row], self.vecs[v])
                        order = np.argsort(ds)[:M]
                        self.links[v][level] = [row[i] for i in order]
                cur = cands[0][1]
            if lvl > self.max_level:
                self.max_level, self.entry = lvl, nid
            ids.append(nid)
        return np.asarray(ids)

    def delete(self, ids):
        for i in np.asarray(ids).ravel():
            if 0 <= i < len(self.alive):
                self.alive[int(i)] = False

    def search(self, queries, k=10):
        out = np.full((len(queries), k), -1, np.int64)
        for qi, q in enumerate(np.asarray(queries, np.float32)):
            if self.entry < 0:
                continue
            cur = self.entry
            for level in range(self.max_level, 0, -1):
                cur = self._search_layer(q, cur, level, 1)[0][1]
            best = self._search_layer(q, cur, 0, self.efs)
            hits = [v for _, v in best if self.alive[v]][:k]
            out[qi, :len(hits)] = hits
        return out


class Vamana:
    """FreshDiskANN-style single-layer graph (R=64, L=128, alpha=1.2)."""

    def __init__(self, dim, R=32, L=64, alpha=1.2, seed=0,
                 consolidate_at=0.2):
        self.dim, self.R, self.L, self.alpha = dim, R, L, alpha
        self.rng = np.random.default_rng(seed)
        self.vecs = np.zeros((0, dim), np.float32)
        self.nbrs: list[np.ndarray] = []
        self.alive: list[bool] = []
        self.consolidate_at = consolidate_at
        self.n_deleted = 0

    def _greedy(self, q, L):
        n = len(self.nbrs)
        if n == 0:
            return []
        start = int(self.rng.integers(n))
        visited = set()
        pool = [(float(_l2(self.vecs[start], q)), start)]
        while True:
            unv = [(d, u) for d, u in pool if u not in visited]
            if not unv:
                break
            d, u = min(unv)
            visited.add(u)
            nb = [v for v in self.nbrs[u] if v >= 0 and v not in visited
                  and v not in {x for _, x in pool}]
            if nb:
                ds = _l2(self.vecs[nb], q)
                pool.extend((float(dv), v) for dv, v in zip(ds, nb))
            pool.sort()
            pool = pool[:L]
        return pool

    def _robust_prune(self, p_vec, cands):
        cands = sorted(set(cands), key=lambda v: float(_l2(self.vecs[v], p_vec)))
        out = []
        for v in cands:
            if len(out) >= self.R:
                break
            dv = float(_l2(self.vecs[v], p_vec))
            ok = True
            for u in out:
                if self.alpha * float(_l2(self.vecs[u], self.vecs[v])) < dv:
                    ok = False
                    break
            if ok:
                out.append(v)
        return np.asarray(out + [-1] * (self.R - len(out)), np.int64)

    def insert(self, vectors):
        ids = []
        for vec in np.asarray(vectors, np.float32):
            nid = len(self.nbrs)
            self.vecs = np.vstack([self.vecs, vec[None]])
            self.alive.append(True)
            pool = self._greedy(vec, self.L)
            cands = [u for _, u in pool if self.alive[u]]
            self.nbrs.append(self._robust_prune(vec, cands)
                             if cands else np.full(self.R, -1, np.int64))
            for v in self.nbrs[nid]:
                if v < 0:
                    continue
                row = [x for x in self.nbrs[v] if x >= 0] + [nid]
                if len(row) > self.R:
                    self.nbrs[v] = self._robust_prune(self.vecs[v], row)
                else:
                    self.nbrs[v] = np.asarray(
                        row + [-1] * (self.R - len(row)), np.int64)
            ids.append(nid)
        return np.asarray(ids)

    def delete(self, ids):
        for i in np.asarray(ids).ravel():
            if 0 <= i < len(self.alive) and self.alive[int(i)]:
                self.alive[int(i)] = False
                self.n_deleted += 1
        if self.n_deleted > self.consolidate_at * max(len(self.alive), 1):
            self.consolidate()

    def consolidate(self):
        for u in range(len(self.nbrs)):
            if not self.alive[u]:
                continue
            row = self.nbrs[u]
            dead = [v for v in row if v >= 0 and not self.alive[v]]
            if not dead:
                continue
            cands = [v for v in row if v >= 0 and self.alive[v]]
            for p in dead:
                cands += [w for w in self.nbrs[p] if w >= 0
                          and self.alive[w] and w != u]
            self.nbrs[u] = self._robust_prune(self.vecs[u], cands) \
                if cands else np.full(self.R, -1, np.int64)
        self.n_deleted = 0

    def search(self, queries, k=10):
        out = np.full((len(queries), k), -1, np.int64)
        for qi, q in enumerate(np.asarray(queries, np.float32)):
            pool = self._greedy(q, self.L)
            hits = [u for _, u in pool if self.alive[u]][:k]
            out[qi, :len(hits)] = hits
        return out


class CagraStatic:
    """Static device-resident index; updates buffer then trigger a full
    rebuild (GPU baselines' behavior under streaming updates)."""

    def __init__(self, dim, degree=32, rebuild_every=4096, seed=0):
        import jax
        from repro.core.build import build_index
        from repro.core.search import search_batch
        from repro.core.types import SearchParams
        self._build_index = build_index
        self._search_batch = search_batch
        self.sp = SearchParams(k=10, pool=64, max_iters=96, policy="never")
        self.dim, self.degree = dim, degree
        self.rebuild_every = rebuild_every
        self.pending = np.zeros((0, dim), np.float32)
        self.data = np.zeros((0, dim), np.float32)
        self.deleted: set[int] = set()
        self.state = None
        self.rebuilds = 0
        self._key = __import__("jax").random.PRNGKey(seed)

    def _maybe_rebuild(self, force=False):
        if len(self.pending) == 0 and not force:
            return
        if not force and len(self.pending) < self.rebuild_every \
                and self.state is not None:
            return
        keep = np.asarray([i for i in range(len(self.data))
                           if i not in self.deleted], np.int64)
        self.data = np.concatenate([self.data[keep], self.pending])
        self.deleted = set()
        self.pending = np.zeros((0, self.dim), np.float32)
        if len(self.data) >= 8:
            cap = max(1024, 1 << int(np.ceil(np.log2(len(self.data) + 1))))
            self.state = self._build_index(self.data, degree=self.degree,
                                           cache_slots=64, n_max=cap,
                                           warm=False)
            self.rebuilds += 1

    def insert(self, vectors):
        base = len(self.data) + len(self.pending)
        self.pending = np.concatenate(
            [self.pending, np.asarray(vectors, np.float32)])
        self._maybe_rebuild()
        return np.arange(base, base + len(vectors))

    def delete(self, ids):
        self.deleted.update(int(i) for i in np.asarray(ids).ravel())

    def search(self, queries, k=10):
        import jax
        self._maybe_rebuild(force=self.state is None)
        if self.state is None:
            return np.full((len(queries), k), -1, np.int64)
        self._key, sub = jax.random.split(self._key)
        res = self._search_batch(self.state,
                                 __import__("jax").numpy.asarray(
                                     queries, np.float32), sub,
                                 self.sp._replace(k=k))
        ids = np.asarray(res.ids)
        # mask deleted-but-not-rebuilt
        mask = np.isin(ids, np.asarray(list(self.deleted), np.int64)) \
            if self.deleted else np.zeros_like(ids, bool)
        return np.where(mask, -1, ids)
