"""Host-side streaming engine: concurrency control + real-time coordination
(paper §4.4, §5.3).

The CUDA multi-stream design maps to host dispatch threads over immutable
jitted programs (DESIGN.md §2): search streams read the last *published*
state snapshot concurrently; a dedicated update stream serializes
insert/delete batches; background consolidation runs on an MVCC snapshot
and merges without blocking foreground traffic.

Consistency guarantees (paper Table 3):
* ``sync=True`` — updates publish atomically under the state lock before
  returning; every subsequent search observes them (read-after-write).
* ``sync=False`` — the ablation: searches read a stale snapshot refreshed
  every ``stale_refresh`` operations, reproducing the paper's
  no-synchronization recall collapse under load.

Also here: adaptive batching (latency/throughput trade, paper Fig. 17),
cold-start warmup (§4.4), deletion-triggered repair/consolidation
scheduling (§5.2), bounded-version policy (§5.3).
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cache as Cache
from repro.core import mvcc, slo, update
from repro.core import wal as walmod
from repro.core.build import build_index
from repro.core.search import search_batch
from repro.core.types import IndexState, SearchParams


@dataclass
class EngineConfig:
    degree: int = 32
    cache_slots: int = 4096
    capacity: int = 1 << 16
    search: SearchParams = field(default_factory=SearchParams)
    repair_every: int = 8          # update batches between repair scans
    repair_budget: int = 256
    consolidate_threshold: float = 0.2   # paper: 20% deleted
    repair_threshold: float = 0.5        # paper: >50% dead neighbors
    max_versions: int = 2                # bounded-version policy
    sync: bool = True
    stale_refresh: int = 64              # ops between refreshes when !sync
    seed: int = 0
    # -- disk tier (paper Fig. 11; three-tier mode when disk_path is set) --
    disk_path: Optional[str] = None      # directory for the memmap tier
    disk_capacity: int = 0               # id-space of the disk tier
    #                                      (0 -> capacity)
    host_window: int = 0                 # host-window slots (0 -> cap // 4)
    prefetch: bool = True                # async frontier prefetcher
    prefetch_budget: int = 32            # ids enqueued per search iteration
    # -- speculative pipeline + cross-query coalescing (paper §4.4) --
    speculate: bool = True               # two-stage speculative tiered arm
    spec_width: int = 0                  # staged guesses/query (0 -> beam)
    spec_rank: str = "auto"              # frontier predictor: auto | flam |
    #                                      dist. "dist" (exact host re-rank)
    #                                      wins only when delta fetches are
    #                                      genuinely IO-bound; "auto" probes
    #                                      the disk tier's per-row fetch
    #                                      latency at startup and picks —
    #                                      ROADMAP records the right default
    #                                      flips between page-cache-backed
    #                                      and real-SSD deployments.
    spec_auto_threshold_us: float = 20.0  # per-row latency above which
    #                                      "auto" resolves to "dist"
    coalesce: bool = True                # adaptive cross-query micro-batching
    coalesce_max_batch: int = 256        # max queries per merged dispatch
    coalesce_window: float = 2e-3        # max adaptive coalescing wait (s)
    # -- SLO-aware serving tier (core/slo.py): per-tenant deadline
    #    admission, p99-targeted coalescing, graceful degradation --
    slo_target_p99: float = 0.0          # per-request p99 target (s): the
    #                                      window controller widens only
    #                                      under it, pressure/shedding are
    #                                      scaled by it. 0 (default) keeps
    #                                      the tier passive: weighted-fair
    #                                      admission + explicit deadlines
    #                                      only, no degradation/shedding,
    #                                      merge-rate window heuristic
    slo_default_deadline: float = 0.0    # deadline (s after submit) for
    #                                      requests that carry none;
    #                                      0 = no implicit deadline
    slo_tenant_weights: Optional[dict] = None  # tenant -> fair-share
    #                                      weight (weighted-fair drain;
    #                                      unlisted tenants weigh 1.0) —
    #                                      weights double as priorities
    slo_degrade_order: tuple = ("rerank_depth", "beam", "fused_rounds")
    #                                      quality knobs halved (in order,
    #                                      cumulatively) as overload
    #                                      pressure rises; shedding is
    #                                      allowed only past the last
    slo_degrade_at: float = 0.5          # pressure (modeled queue wait /
    #                                      target p99) engaging level 1
    slo_shed_at: float = 1.0             # modeled-wait/target above which
    #                                      a maxed-degradation tenant is
    #                                      shed at admission
    slo_restore_after: int = 4           # calm dispatches per one-level
    #                                      degradation restore
    slo_tenant_rate_limits: Optional[dict] = None  # tenant -> requests/s
    #                                      (or (rate, burst)): token bucket
    #                                      at admission; an empty bucket
    #                                      rejects with slo.RateLimitError,
    #                                      counted per tenant in
    #                                      stats()["slo"]
    wavp_cascade_promote: bool = True    # cascade hits displace frozen slots
    # -- PQ code lane (quant.py): device-resident ADC scan + exact re-rank
    pq_enabled: bool = False             # coarse-then-refine tiered search
    pq_m: int = 16                       # subspaces (largest divisor of dim
    #                                      <= this is used; codes are m
    #                                      bytes/vector vs dim*4 exact)
    pq_bits: int = 8                     # bits/code (K = 2^bits centroids)
    pq_train_iters: int = 20             # Lloyd sweeps at index time
    pq_train_sample: int = 4096          # codebook training sample rows
    rerank_depth: int = 32               # pool entries exactly re-ranked
    #                                      through the cascade (0 -> pool;
    #                                      == pool pins exact-path parity)
    # -- fused multi-round executor (PQ mode): device-resident topology
    #    tier + K-round lax.while_loop dispatch --
    topo_cache_slots: int = 0            # adjacency-row slots on device
    #                                      (0 -> disk capacity: full
    #                                      residency, warmed at init so
    #                                      steady state is 3 dispatches;
    #                                      < 0 disables the fused path)
    fused_rounds: int = 0                # K-round budget per fused
    #                                      dispatch (0 -> uncapped: one
    #                                      dispatch covers every in-cache
    #                                      round)
    # -- durability (core/wal.py): WAL + epoch-fenced snapshots --
    wal_enabled: bool = True             # log each update op to a CRC-framed
    #                                      WAL before mutating the store;
    #                                      reopening an engine on a disk_path
    #                                      with a published manifest recovers
    #                                      (snapshot + WAL replay) instead of
    #                                      rebuilding
    wal_group_commit: int = 8            # records per fsync (group commit);
    #                                      1 = fsync every op
    snapshot_every_epochs: int = 512     # update batches (write epochs)
    #                                      between automatic snapshot
    #                                      publications; 0 = publish only at
    #                                      open and close
    # -- filtered search (core/filters.py): per-id attribute store +
    #    in-dispatch predicate lane --
    attributes: Optional[object] = None  # filters.AttributeSchema: fixed
    #                                      tag/numeric columns per id
    #                                      (tiered mode only). Enables
    #                                      search(filter=FilterSpec(...))
    filter_fallback_selectivity: float = 0.1  # sampled selectivity below
    #                                      which a filtered query routes to
    #                                      the brute-force ADC scan over
    #                                      the matched set (a graph walk
    #                                      starves when almost nothing
    #                                      passes); 0 disables the fallback
    cache_dtype: str = "bf16"            # exact-cache payload dtype:
    #                                      bf16 halves device vector bytes
    #                                      (re-rank upcasts to fp32);
    #                                      "fp32" restores bit-exactness
    build_partitions: int = 1            # partitioned graph build (bounded
    #                                      memory window; used by --scale)
    build_cross_samples: int = 128       # cross-partition candidate columns
    #                                      per partition (graph quality at
    #                                      scale hinges on this)


class ReadOnlyEngineError(RuntimeError):
    """The WAL device failed: the engine degraded to read-only (searches
    keep serving; updates raise this instead of risking an unlogged
    mutation). ``stats()["degraded"]`` reports the mode."""


class _SearchFuture:
    """Demux handle for one coalesced search request. Carries the SLO
    admission metadata: ``tenant`` names the per-tenant queue it joins
    and ``deadline`` (absolute ``perf_counter`` time, or None) lets the
    dispatcher skip-and-fail it once unmeetable."""

    __slots__ = ("queries", "submitted", "_event", "ids", "dists", "error",
                 "latency", "tenant", "deadline", "filter", "fkey")

    def __init__(self, queries, tenant=None, deadline=None, filter=None):
        self.queries = queries
        self.submitted = time.perf_counter()
        self._event = threading.Event()
        self.ids = None
        self.dists = None
        self.error = None
        self.latency = 0.0
        self.tenant = slo.DEFAULT_TENANT if tenant is None else str(tenant)
        # relative seconds -> absolute deadline on the submit clock
        self.deadline = None if deadline is None \
            else self.submitted + float(deadline)
        # filter-spec compatibility class: the serving tier coalesces
        # only requests whose fkey matches (one dispatch, one predicate)
        self.filter = filter
        self.fkey = None if filter is None else filter.key()

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout=None):
        if not self._event.wait(timeout):
            raise TimeoutError("coalesced search did not complete")
        if self.error is not None:
            raise self.error
        return self.ids, self.dists


class CoalescingScheduler:
    """SLO-aware adaptive cross-query coalescing (paper §4.4, adaptive
    resource management): requests arriving within a short window — or
    until the micro-batch fills — are stacked into ONE executor
    invocation and the results are demultiplexed per request, so N
    concurrent submitters share each round's fixed dispatch cost instead
    of paying it N times.

    Admission runs through the serving tier (``core.slo.ServingTier``):
    per-tenant queues drained weighted-fair, deadline-unmeetable
    requests skipped-and-failed, and — once degradation is maxed —
    over-SLO tenants shed at admission. The coalescing window is
    **p99-targeted**: a reservoir of per-request end-to-end latencies is
    kept, and the window widens only while the observed p99 is under the
    policy target AND requests actually merged; it halves when a
    dispatch went out uncoalesced (light load — a lone caller converges
    to ~direct-call p50) or when p99 overshoots the target (queueing is
    eating the budget), clamped to [min_window, max_window]. Under
    pressure the tier degrades search quality (``slo.degrade_params``
    applied by the search_fn via ``degrade=level``) before any request
    is shed."""

    def __init__(self, search_fn, *, max_batch=256, max_window=2e-3,
                 min_window=5e-5, policy: Optional[slo.SLOPolicy] = None):
        self._search = search_fn
        self.tier = slo.ServingTier(policy)
        self._stop = threading.Event()
        self._th: Optional[threading.Thread] = None
        self._th_lock = threading.Lock()
        self.max_batch = max_batch
        self.max_window = max_window
        self.min_window = min_window
        self.window = min_window
        self.requests = 0      # requests served
        self.queries = 0       # query rows served
        self.dispatches = 0    # merged executor invocations
        self.coalesced = 0     # dispatches that merged > 1 request
        self.degraded_dispatches = 0  # dispatches run at level > 0

    # -- client side ----------------------------------------------------
    def submit(self, queries, tenant=None, deadline=None,
               filter=None) -> _SearchFuture:
        """Enqueue one request. ``tenant`` keys the fair-share admission
        queue (None -> default tenant); ``deadline`` is seconds from now
        after which the result is worthless (None -> policy default);
        ``filter`` is a ``filters.FilterSpec`` — only requests with an
        equal spec share a dispatch (the tier demuxes by ``fkey``).
        A shed request comes back as a future already failed with
        ``slo.LoadShedError``."""
        fut = _SearchFuture(np.asarray(queries, np.float32),
                            tenant=tenant, deadline=deadline,
                            filter=filter)
        self._ensure_started()
        self.tier.offer(fut)   # raises after stop(); sheds via the future
        return fut

    def search(self, queries, tenant=None, deadline=None, filter=None):
        return self.submit(queries, tenant=tenant,
                           deadline=deadline, filter=filter).result()

    # -- dispatcher -----------------------------------------------------
    def _ensure_started(self):
        if self._th is not None and self._th.is_alive():
            return
        with self._th_lock:
            if self.tier.closed:
                return
            if self._th is None or not self._th.is_alive():
                self._th = threading.Thread(target=self._run, daemon=True)
                self._th.start()

    def _run(self):
        while not self._stop.is_set():
            batch = self.tier.collect(self.max_batch, self.window,
                                      self._stop)
            if not batch:
                continue
            rows = sum(len(f.queries) for f in batch)
            level = self.tier.level
            ok = True
            t0 = time.perf_counter()
            try:
                kw = {"degrade": level} if level > 0 else {}
                if batch[0].filter is not None:
                    # the tier guarantees a filter-homogeneous batch
                    kw["filter"] = batch[0].filter
                ids, dists = self._search(
                    np.concatenate([f.queries for f in batch], axis=0),
                    **kw)
                off = 0
                now = time.perf_counter()
                for f in batch:
                    b = len(f.queries)
                    f.ids, f.dists = ids[off:off + b], dists[off:off + b]
                    f.latency = now - f.submitted
                    off += b
            except Exception as e:
                ok = False
                for f in batch:
                    f.error = e
            finally:
                dt = time.perf_counter() - t0
                self.requests += len(batch)
                self.queries += rows
                self.dispatches += 1
                if level > 0:
                    self.degraded_dispatches += 1
                if len(batch) > 1:
                    self.coalesced += 1
                self.tier.complete(batch, rows, dt, ok=ok)
                for f in batch:
                    f._event.set()
                self._adapt_window(len(batch))

    def _adapt_window(self, merged: int):
        """p99-targeted window control. Shrink on an uncoalesced dispatch
        (idle convergence to the direct-call path) or when request p99
        overshoots the target (wider windows add queueing latency we can
        no longer afford); widen ONLY while merging is happening and p99
        still has headroom under the target."""
        if merged == 1:
            self.window = max(self.min_window, self.window * 0.5)
            return
        target = self.tier.policy.target_p99
        p99 = self.tier.lat.quantile(99)   # dispatcher-only read
        if target > 0 and p99 is not None and p99 > target:
            self.window = max(self.min_window, self.window * 0.5)
        else:
            # no target configured -> legacy merge-rate heuristic
            # (merging happened, widen); under a target, widen only
            # while p99 has headroom
            self.window = min(self.max_window, self.window * 2.0)

    def stop(self, join_timeout: float = 5.0):
        """Terminal shutdown: stop the dispatcher and FAIL any request
        still queued — an orphaned future would otherwise hang its caller
        forever in ``result()``. Submissions after stop() raise. The
        drain shares the tier's lock with the dispatcher's queue pops
        (which refuse once ``closed`` is set), so a slow-to-exit
        dispatcher and the drain can never complete the same future
        twice; a dispatcher that outlives ``join_timeout`` (an executor
        call that never returns) raises AFTER the queued futures are
        failed, so no caller is left hanging either way."""
        self.tier.close()
        self._stop.set()
        th = self._th
        if th is not None:
            th.join(timeout=join_timeout)
        self.tier.drain(RuntimeError(
            "CoalescingScheduler stopped before this request was "
            "dispatched"))
        if th is not None and th.is_alive():
            raise RuntimeError(
                "CoalescingScheduler dispatcher did not exit within "
                f"{join_timeout}s of stop(): the executor call is stuck; "
                "its in-flight futures may never complete")
        self._th = None


class SVFusionEngine:
    """Thread-safe streaming SANNS engine over the functional core.

    Two serving modes share one interface:

    * **device mode** (default): the capacity tier is the in-memory
      ``GraphState``; search/insert run as jitted transforms.
    * **three-tier mode** (``cfg.disk_path`` set): the capacity tier is a
      ``TieredStore`` host window over disk memmaps. Searches cascade
      device cache → host window → disk; the host owns the traversal, the
      device runs the per-expansion distance batches, and predicted-hot
      frontiers are enqueued to the async prefetcher so disk reads overlap
      with device compute. WAVP's F_λ drives both device-cache promotion
      and host-window demotion order. Localized repair is subsumed by the
      streaming consolidation pass, which (like device mode) runs on an
      MVCC snapshot: topology+alive are frozen briefly, rows rebuild in
      the background, and the merge re-applies the window's reverse-edge
      log — deletion-heavy maintenance blocks neither updates nor
      searches.

    Both modes search through the shared hop-batched frontier executor
    (``core.search``): ``sp.beam`` frontier expansions per round, one
    jitted gather+distance+topk-merge dispatch per round.
    """

    def __init__(self, init_vectors, cfg: EngineConfig, init_attrs=None):
        self.cfg = cfg
        self._init_attrs = init_attrs      # seed attributes (tiered mode)
        self._key = jax.random.PRNGKey(cfg.seed)
        self._state_lock = threading.RLock()   # publish/subscribe
        self._update_lock = threading.Lock()   # serializes the update stream
        self._cache_lock = threading.Lock()
        self._backend = None                   # TieredBackend in 3-tier mode
        self._placement = None                 # HostPlacement in 3-tier mode
        self._rng = np.random.default_rng(cfg.seed)
        self._spec_rank = cfg.spec_rank    # resolved by the tiered probe
        self._spec_probe_us = None
        self._wal = None                   # wal.WriteAheadLog (tiered mode)
        self._recovery = None              # wal.recover report when reopened
        self._durable_epoch = None         # last published manifest epoch
        self._degraded = None              # read-only reason once WAL fails
        self._batches_since_snapshot = 0
        if init_vectors is not None:
            init_vectors = np.asarray(init_vectors, np.float32)
        if cfg.pq_enabled and not cfg.disk_path:
            raise ValueError(
                "pq_enabled requires the three-tier mode (set disk_path): "
                "the PQ code lane rides the tiered executor; device mode "
                "would silently serve exact fp32 instead")
        if cfg.attributes is not None and not cfg.disk_path:
            raise ValueError(
                "attributes (filtered search) require the three-tier mode "
                "(set disk_path): the attribute store rides the tiered "
                "backend")
        if init_attrs is not None and cfg.attributes is None:
            raise ValueError("init_attrs passed but cfg.attributes is "
                             "unset: declare the attribute schema")
        if cfg.disk_path:
            self._init_tiered(init_vectors, cfg)
        else:
            if init_vectors is None:
                raise ValueError("device mode has no durable state to "
                                 "recover: init_vectors is required")
            self._state = build_index(
                init_vectors, degree=cfg.degree,
                cache_slots=cfg.cache_slots, n_max=cfg.capacity)
        self._stale_state = self._state
        self._ops_since_refresh = 0
        self._update_batches = 0
        self._batches_since_repair = 0
        self._consolidations = 0
        self._active_versions = 0
        self._rev_logs: list = []
        self._snapshot_n: Optional[int] = None
        self._search_rounds = 0        # tiered executor round accounting
        self._search_dispatches = 0    # device dispatches issued by search
        self._search_batches = 0
        self._spec_hits = 0            # speculative-pipeline frontier hits
        self._spec_misses = 0
        self._topo_hits = 0            # fused-loop topology-cache hits
        self._topo_misses = 0
        self._filtered_searches = 0    # filtered-search batch counter
        self._filter_fallbacks = 0     # ... of which took the brute-force
        #                                low-selectivity path
        self._filter_last_selectivity = None
        self._filter_last_path = None
        self._coalescer = (CoalescingScheduler(
            self._search_exec, max_batch=cfg.coalesce_max_batch,
            max_window=cfg.coalesce_window,
            policy=slo.SLOPolicy(
                target_p99=cfg.slo_target_p99,
                default_deadline=cfg.slo_default_deadline,
                tenant_weights=cfg.slo_tenant_weights,
                degrade_order=tuple(cfg.slo_degrade_order),
                degrade_at=cfg.slo_degrade_at,
                shed_at=cfg.slo_shed_at,
                restore_after=cfg.slo_restore_after,
                tenant_rate_limits=cfg.slo_tenant_rate_limits))
            if cfg.coalesce else None)
        self._bg_threads: list = []
        self.latencies: dict[str, list] = {"search": [], "insert": [],
                                           "delete": []}

    def _init_tiered(self, init_vectors, cfg: EngineConfig):
        from repro.core.build import build_tiered_backend
        from repro.core.types import init_graph_state, init_stats
        man = walmod.load_manifest(cfg.disk_path)
        if man is not None:
            # crash/restart path: the directory holds a published durable
            # epoch — recover it (snapshot + WAL replay) instead of
            # rebuilding, and refuse ambiguous mixes loudly
            if init_vectors is not None and len(init_vectors):
                raise ValueError(
                    "disk_path holds a published durable index; pass "
                    "init_vectors=None to recover it, or point disk_path "
                    "at a fresh directory to build")
            if self._init_attrs is not None:
                raise ValueError(
                    "disk_path holds a published durable index; seed "
                    "attributes (init_attrs) only apply to a fresh build")
            if not cfg.wal_enabled:
                raise ValueError(
                    "disk_path holds a published durable index but "
                    "wal_enabled=False: recovering without a WAL would "
                    "leave subsequent updates unlogged under a manifest "
                    "that claims durability")
            if bool(man.get("pq")) != bool(cfg.pq_enabled):
                raise ValueError(
                    f"pq_enabled={cfg.pq_enabled} does not match the "
                    f"durable index (manifest pq={man.get('pq')!r})")
            cap = int(man["capacity"])
            window = cfg.host_window or max(64, cap // 4)
            self._backend, self._wal, self._recovery = walmod.recover(
                cfg.disk_path, host_window=window,
                group_commit=cfg.wal_group_commit)
            self._durable_epoch = int(man["epoch"])
            n, dim = self._backend.n, self._backend.dim
        else:
            if init_vectors is None or not len(init_vectors):
                raise ValueError(
                    "nothing to recover: disk_path has no published "
                    "manifest and no init_vectors were given")
            if len(init_vectors) < 2 * cfg.degree:
                raise ValueError("three-tier mode needs >= 2*degree seed "
                                 "vectors to bootstrap the graph")
            n, dim = init_vectors.shape
            cap = cfg.disk_capacity or cfg.capacity
            self._backend = build_tiered_backend(
                init_vectors, cfg.degree, cfg.disk_path, disk_capacity=cap,
                host_window=cfg.host_window, seed=cfg.seed,
                n_partitions=cfg.build_partitions,
                cross_samples=cfg.build_cross_samples)
        if cfg.attributes is not None:
            from repro.core.tiers import AttributeStore
            if self._backend.attrs is None:
                if man is not None:
                    # pre-attribute manifest: recovery proceeds with an
                    # empty store (columns default; filters still work,
                    # matching nothing non-default) — backward compat
                    self._backend.attach_attrs(
                        AttributeStore(cfg.attributes, cap))
                else:
                    tags, nums = cfg.attributes.coerce(self._init_attrs, n)
                    self._backend.attach_attrs(AttributeStore(
                        cfg.attributes, cap, tags=tags, nums=nums))
            elif self._backend.attrs.schema != cfg.attributes:
                raise ValueError(
                    f"attribute schema mismatch: config declares "
                    f"{cfg.attributes}, the durable index recovered "
                    f"{self._backend.attrs.schema}")
        if cfg.cache_dtype not in ("bf16", "fp32"):
            raise ValueError(f"cache_dtype must be bf16|fp32, got "
                             f"{cfg.cache_dtype!r}")
        cache_dtype = jnp.bfloat16 if cfg.cache_dtype == "bf16" \
            else np.float32
        self._placement = Cache.HostPlacement(cap, cfg.cache_slots, dim,
                                              dtype=cache_dtype)
        if cfg.pq_enabled:
            if self._backend.pq is None:
                # fresh build: train per-subspace Lloyd codebooks on a
                # sample, encode the whole seed set, attach the
                # unconditionally resident code lane (recovery attached
                # the lane from the persisted codebook + codes instead)
                from repro.core import quant
                m = quant.choose_m(dim, cfg.pq_m)
                cb = quant.train_codebook(
                    init_vectors, m, cfg.pq_bits, iters=cfg.pq_train_iters,
                    sample=cfg.pq_train_sample, seed=cfg.seed)
                self._backend.attach_pq(quant.PQCodes(
                    cb, cap, codes=quant.encode(cb, init_vectors)))
            if cfg.topo_cache_slots >= 0:
                # device-resident topology tier for the fused multi-round
                # executor; 0 slots -> full residency, warmed so the
                # first search batch already runs at 3 dispatches/query.
                # A pure cache of the store's adjacency truth: recovery
                # re-warms it here from the recovered host state.
                Cache.warm_topo_cache(self._backend, cfg.topo_cache_slots)
        # spec_rank="auto": probe the disk tier's per-row delta-fetch
        # latency once and pick the frontier predictor from it (the right
        # default flips between page-cache-backed and real-SSD tiers).
        # Without speculation the predictor is dead state — skip the
        # probe, which costs a flush + page-cache eviction of probed
        # ranges the first search batches would have hit warm.
        if cfg.spec_rank == "auto":
            if cfg.speculate:
                from repro.core.tiers import probe_fetch_latency
                self._spec_probe_us = probe_fetch_latency(self._backend,
                                                          seed=cfg.seed)
                self._spec_rank = ("dist" if self._spec_probe_us
                                   >= cfg.spec_auto_threshold_us
                                   else "flam")
            else:
                self._spec_rank = "flam"   # predictor unused; stats must
                #                            still report a concrete one
        # cold-start warm-up (paper §4.4): preload top-E_in rows
        warm_n = min(cfg.cache_slots, n)
        score = np.where(self._backend.alive[:n],
                         self._backend.e_in[:n], -1)
        top = np.argsort(-score, kind="stable")[:warm_n]
        vecs, _ = self._backend.store.peek(top)
        self._placement.warm(top, vecs)
        # graph is a 1-row stub: in tiered mode the capacity tier lives
        # behind the store, and any device-path use fails loudly
        self._state = IndexState(
            graph=init_graph_state(1, dim, cfg.degree),
            cache=self._placement.to_cache_state(),
            stats=init_stats(), tiered=self._backend)
        if cfg.prefetch:
            self._backend.store.start_prefetcher()
        if cfg.wal_enabled:
            if man is None:
                # epoch 0: publish the freshly built index as a durable
                # snapshot so the first update op already logs against a
                # recoverable base
                manifest, self._wal = walmod.publish_snapshot(
                    cfg.disk_path, self._backend, None,
                    group_commit=cfg.wal_group_commit)
                self._durable_epoch = int(manifest["epoch"])
            self._backend.wal = self._wal

    # ------------------------------------------------------------------
    def _next_key(self):
        with self._cache_lock:
            self._key, sub = jax.random.split(self._key)
        return sub

    def _read_state(self) -> IndexState:
        if self.cfg.sync:
            with self._state_lock:
                return self._state
        # no-sync ablation: stale snapshot, periodically refreshed
        self._ops_since_refresh += 1
        if self._ops_since_refresh >= self.cfg.stale_refresh:
            self._ops_since_refresh = 0
            with self._state_lock:
                self._stale_state = self._state
        return self._stale_state

    def _publish(self, state: IndexState):
        with self._state_lock:
            self._state = state

    # ------------------------------------------------------------------
    def search(self, queries, update_cache=True, tenant=None,
               deadline=None, filter=None):
        """Batched search. Returns (ids, dists) as numpy. With coalescing
        enabled (default) the request joins the engine's adaptive
        cross-query micro-batch through the SLO serving tier: concurrent
        callers are stacked into ONE executor invocation and
        demultiplexed, the window shrinks itself under light load so a
        lone caller pays ~the direct-call latency, and under overload
        search quality degrades (then, last, the over-SLO tenant sheds)
        rather than tail latency growing unboundedly (paper §4.4
        adaptive resource management). ``tenant`` keys the weighted-fair
        admission queue; ``deadline`` (seconds from now) lets the
        dispatcher skip the request once unmeetable — both failure modes
        raise (``slo.LoadShedError`` / ``slo.DeadlineMissError``).
        ``filter`` (a ``filters.FilterSpec``) restricts results to ids
        whose attributes pass the predicate — requires
        ``cfg.attributes``; only filter-spec-equal requests coalesce."""
        queries = np.asarray(queries, np.float32)
        if self._coalescer is not None and update_cache and len(queries):
            return self._coalescer.search(queries, tenant=tenant,
                                          deadline=deadline, filter=filter)
        return self._search_exec(queries, update_cache, filter=filter)

    def submit_search(self, queries, tenant=None, deadline=None,
                      filter=None):
        """Async entry to the coalescing scheduler: returns a future-like
        handle (``.result() -> (ids, dists)``, ``.latency``). Concurrent
        submitters share executor dispatches; ``tenant``/``deadline``/
        ``filter`` as in ``search`` (only filter-spec-equal requests
        share a dispatch)."""
        queries = np.asarray(queries, np.float32)
        if self._coalescer is None:
            fut = _SearchFuture(queries, tenant=tenant, deadline=deadline,
                                filter=filter)
            try:
                fut.ids, fut.dists = self._search_exec(queries,
                                                       filter=filter)
                fut.latency = time.perf_counter() - fut.submitted
            except Exception as e:   # pragma: no cover - surfaced by result()
                fut.error = e
            fut._event.set()
            return fut
        return self._coalescer.submit(queries, tenant=tenant,
                                      deadline=deadline, filter=filter)

    def _degraded_knobs(self, degrade: int):
        """SearchParams + rerank depth at degradation ``degrade`` (the
        serving tier's pressure level): level 0 is the configured
        quality; deeper levels shrink knobs per ``slo_degrade_order``.
        The level count is bounded by the order's length, so at most
        len(order) extra executor shapes ever compile."""
        return slo.degrade_params(self.cfg.search, self.cfg.rerank_depth,
                                  degrade,
                                  tuple(self.cfg.slo_degrade_order))

    def _search_exec(self, queries, update_cache=True, degrade=0,
                     filter=None):
        """One executor invocation (the coalescer's dispatch target).
        ``degrade`` > 0 dispatches at reduced search quality (graceful
        degradation under overload — see ``core.slo``)."""
        if self._backend is not None:
            return self._search_tiered(queries, update_cache,
                                       degrade=degrade, filter=filter)
        if filter is not None:
            raise ValueError("filtered search requires the three-tier "
                             "mode with cfg.attributes set")
        t0 = time.perf_counter()
        sp, _ = self._degraded_knobs(degrade)
        st = self._read_state()
        queries = jnp.asarray(queries, jnp.float32)
        B = queries.shape[0]
        Bp = 1 << max(0, (B - 1)).bit_length()
        if Bp != B:
            queries = jnp.concatenate(
                [queries, jnp.zeros((Bp - B, queries.shape[1]), queries.dtype)])
        res = search_batch(st, queries, self._next_key(), sp)
        if Bp != B:
            lane = jnp.arange(Bp)[:, None] < B   # mask pad lanes out of logs
            res = res._replace(ids=res.ids[:B], dists=res.dists[:B],
                               acc_ids=jnp.where(lane, res.acc_ids, -1),
                               acc_hit=res.acc_hit & lane)
        ids = np.asarray(res.ids)
        if update_cache:
            # cache placement is applied to the *current* state (the cache
            # tier is shared; graph fields pass through untouched)
            with self._state_lock:
                cur = self._state
                new = Cache.apply_wavp(cur, res.acc_ids, res.acc_hit,
                                       self.cfg.search,
                                       now=self._update_batches)
                self._state = cur._replace(cache=new.cache, stats=new.stats)
        self.latencies["search"].append(time.perf_counter() - t0)
        return ids, np.asarray(res.dists)

    def _search_tiered(self, queries, update_cache=True, degrade=0,
                       filter=None):
        """Three-tier search: speculative pipeline + cascading lookup +
        post-batch host placement. Batches are padded to power-of-two
        buckets so the coalescer's variable micro-batch sizes compile
        O(log) dispatch specializations, not one per size. ``degrade``
        dispatches with the serving tier's reduced-quality knobs (beam /
        hop budget / re-rank depth per ``slo_degrade_order``)."""
        from repro.core.search import search_tiered
        t0 = time.perf_counter()
        with self._cache_lock:
            seed = int(self._rng.integers(0, 2 ** 31 - 1))
        backend = self._backend
        sp, rerank_depth = self._degraded_knobs(degrade)
        queries = np.asarray(queries, np.float32)
        B = queries.shape[0]
        Bp = 1 << max(0, (B - 1)).bit_length()
        if Bp != B:
            queries = np.concatenate(
                [queries, np.zeros((Bp - B, queries.shape[1]), np.float32)])
        f_lam = self._placement.scores(backend.e_in)   # one O(N) pass/batch
        res = search_tiered(
            self._backend, self._placement, queries, seed, sp,
            f_lam=f_lam,
            prefetch_budget=(self.cfg.prefetch_budget if self.cfg.prefetch
                             else 0),
            speculate=self.cfg.speculate, spec_width=self.cfg.spec_width,
            spec_rank=self._spec_rank,
            pq=(backend.pq if self.cfg.pq_enabled else None),
            rerank_depth=rerank_depth,
            topo=(backend.topo if self.cfg.pq_enabled else None),
            fused_rounds=self.cfg.fused_rounds,
            filter=filter,
            filter_fallback_selectivity=self.cfg.filter_fallback_selectivity)
        if Bp != B:   # drop pad lanes from results AND placement logs
            res = res._replace(ids=res.ids[:B], dists=res.dists[:B],
                               acc_ids=res.acc_ids[:B],
                               acc_hit=res.acc_hit[:B])
        with self._cache_lock:    # concurrent search streams share these
            self._search_rounds += res.iters
            self._search_dispatches += res.dispatches
            self._search_batches += 1
            self._spec_hits += res.spec_hits
            self._spec_misses += res.spec_misses
            self._topo_hits += res.topo_hits
            self._topo_misses += res.topo_misses
            if res.filter_path != "none":
                self._filtered_searches += 1
                if res.filter_path == "fallback":
                    self._filter_fallbacks += 1
                self._filter_last_selectivity = res.filter_selectivity
                self._filter_last_path = res.filter_path
        if update_cache:
            with self._cache_lock:
                Cache.apply_wavp_host(
                    self._placement, res.acc_ids, res.acc_hit,
                    self.cfg.search, alive=backend.alive,
                    e_in=backend.e_in,
                    fetch_vectors=lambda i: backend.store.fetch(
                        i, f_lam, count=False)[0],
                    now=self._update_batches,
                    cascade_promote=self.cfg.wavp_cascade_promote)
        self.latencies["search"].append(time.perf_counter() - t0)
        return res.ids, res.dists

    def insert(self, vectors, chunk=512, attributes=None):
        """Insert vectors (chunked so each chunk links into the graph the
        previous chunks built; a near-empty index is bootstrapped with an
        exact KNN stitch among the first chunk). ``attributes`` (dict of
        column -> per-row values, see ``filters.AttributeSchema.coerce``)
        tags the batch for filtered search — requires ``cfg.attributes``
        and the three-tier mode."""
        t0 = time.perf_counter()
        self._check_writable()
        vectors = np.asarray(vectors, np.float32)
        attr_cols = None
        if attributes is not None:
            if self._backend is None or self._backend.attrs is None:
                raise ValueError("insert(attributes=...) requires the "
                                 "three-tier mode with cfg.attributes set")
            attr_cols = self._backend.attrs.schema.coerce(
                attributes, len(vectors))
        out = []
        with self._update_lock:
            for s in range(0, len(vectors), chunk):
                part_np = vectors[s:s + chunk]
                if self._backend is not None:
                    with self._cache_lock:
                        seed = int(self._rng.integers(0, 2 ** 31 - 1))
                    part_attrs = None
                    if attr_cols is not None:
                        part_attrs = (attr_cols[0][s:s + chunk],
                                      attr_cols[1][s:s + chunk])
                    try:
                        ids, rev = update.insert_tiered(
                            self._backend, self._placement, part_np,
                            self.cfg.search, seed, attributes=part_attrs)
                    except walmod.WALWriteError as e:
                        self._degrade(str(e))
                    if self._snapshot_n is not None and len(rev.v):
                        # consolidation in flight: log the window's
                        # reverse edges for the MVCC merge
                        self._rev_logs.append(rev)
                    topo = self._backend.topo
                    if topo is not None and len(ids):
                        # write-through topology install: freshly linked
                        # rows become device-resident immediately, so the
                        # next fused search never miss-exits on them
                        # (reverse-edge updates to OTHER resident rows are
                        # covered by the write-epoch fence wholesale
                        # re-read). Uses the same F_λ eviction order as
                        # demand installs when the cache is partial.
                        arr = np.asarray(ids, np.int64)
                        topo.install(
                            arr, self._backend.store.peek_rows(arr),
                            self._placement.scores(self._backend.e_in))
                    self._update_batches += 1
                    self._batches_since_repair += 1
                    self._batches_since_snapshot += 1
                    out.append(np.asarray(ids))
                    continue
                part = jnp.asarray(part_np)
                st = self._state
                if int(st.graph.alive.sum()) < 2 * self.cfg.degree:
                    st2, ids = self._bootstrap_insert(st, part)
                    rev = None
                else:
                    st2, ids, rev = update.insert_batch(
                        st, part, self._next_key(), self.cfg.search)
                if rev is not None and self._snapshot_n is not None:
                    self._rev_logs.append(rev)
                self._publish(st2)
                self._update_batches += 1
                self._batches_since_repair += 1
                out.append(np.asarray(ids))
        self._maybe_maintain()
        self._maybe_checkpoint()
        self.latencies["insert"].append(time.perf_counter() - t0)
        return np.concatenate(out)

    def _bootstrap_insert(self, st, part):
        """Exact-KNN stitch for a (near-)empty index."""
        from repro.core.build import _exact_knn, compute_e_in
        g = st.graph
        n0 = int(g.n)
        bi = part.shape[0]
        ids = n0 + jnp.arange(bi, dtype=jnp.int32)
        vectors = g.vectors.at[ids].set(part)
        alive = g.alive.at[ids].set(True)
        live_ids = np.where(np.asarray(alive[:n0 + bi]))[0]
        sub = vectors[jnp.asarray(live_ids)]
        knn = _exact_knn(sub, min(g.degree, max(1, len(live_ids) - 1)))
        rows = jnp.asarray(live_ids)[jnp.clip(knn, 0)]
        rows = jnp.where(knn >= 0, rows, -1)
        pad = g.degree - rows.shape[1]
        if pad > 0:
            rows = jnp.concatenate(
                [rows, jnp.full((rows.shape[0], pad), -1, jnp.int32)], 1)
        nbrs = g.nbrs.at[jnp.asarray(live_ids)].set(rows.astype(jnp.int32))
        g = g._replace(vectors=vectors, alive=alive, nbrs=nbrs,
                       n=jnp.asarray(n0 + bi, jnp.int32))
        g = g._replace(e_in=compute_e_in(g.nbrs, g.capacity))
        return st._replace(graph=g), ids

    def delete(self, ids):
        t0 = time.perf_counter()
        self._check_writable()
        with self._update_lock:
            if self._backend is not None:
                # bounds/alive filtering + WAL-before-write live in
                # update.delete_tiered (out-of-range ids are ignored,
                # matching delete_batch's clip semantics)
                try:
                    update.delete_tiered(self._backend, ids)
                except walmod.WALWriteError as e:
                    self._degrade(str(e))
            else:
                st2 = update.delete_batch(self._state,
                                          jnp.asarray(ids, jnp.int32))
                self._publish(st2)
            self._update_batches += 1
            self._batches_since_repair += 1
            self._batches_since_snapshot += 1
        self._maybe_maintain()
        self._maybe_checkpoint()
        self.latencies["delete"].append(time.perf_counter() - t0)

    # ------------------------------------------------------------------
    # durability (core/wal.py)
    def _check_writable(self):
        if self._degraded:
            raise ReadOnlyEngineError(
                f"engine is read-only (WAL degraded): {self._degraded}")

    def _degrade(self, reason: str):
        """WAL device failure: graceful degradation to read-only. The
        failing op was NOT applied (WAL-before-write); searches keep
        serving the pre-failure state."""
        self._degraded = reason
        raise ReadOnlyEngineError(
            f"WAL write failed; engine degraded to read-only: {reason}")

    def checkpoint(self) -> Optional[int]:
        """Publish the current state as a durable epoch (fsync'd snapshot
        + manifest rename + WAL segment rotation; see
        ``wal.publish_snapshot``). Returns the published epoch, or None
        when the engine has no WAL (device mode / wal_enabled=False)."""
        if self._wal is None or self._wal.closed:
            return None
        self._check_writable()
        with self._update_lock:
            try:
                manifest, new_wal = walmod.publish_snapshot(
                    self.cfg.disk_path, self._backend, self._wal,
                    group_commit=self.cfg.wal_group_commit)
            except (OSError, walmod.WALWriteError) as e:
                self._degrade(f"snapshot publish failed: {e}")
            self._wal = new_wal
            self._backend.wal = new_wal
            self._durable_epoch = int(manifest["epoch"])
            self._batches_since_snapshot = 0
        return self._durable_epoch

    def _maybe_checkpoint(self):
        k = self.cfg.snapshot_every_epochs
        if (self._wal is None or self._degraded or k <= 0
                or self._batches_since_snapshot < k):
            return
        self.checkpoint()

    # ------------------------------------------------------------------
    def _maybe_maintain(self):
        """Deletion-triggered maintenance (paper §5.2). Repair fires once
        per ``repair_every`` update batches (counted since the last scan,
        not by a modulo that triggers on the very first batch); the
        deleted fraction is read from a state snapshot taken under the
        lock. Tiered mode has no localized-repair stage — the streaming
        consolidation covers it."""
        with self._update_lock:
            due = self._batches_since_repair >= self.cfg.repair_every
            if due:
                self._batches_since_repair = 0
                if self._backend is None:
                    with self._state_lock:
                        st = self._state
                    st, nrep = update.repair_affected(
                        st, max_repair=self.cfg.repair_budget,
                        threshold=self.cfg.repair_threshold)
                    # repair only touches the graph: publish that field
                    # alone so cache/stats updates from searches that ran
                    # during the scan are not rolled back
                    with self._state_lock:
                        self._state = self._state._replace(graph=st.graph)
        if self._backend is not None:
            frac = self._backend.deleted_fraction()
        else:
            with self._state_lock:
                graph = self._state.graph
            frac = float(update.deleted_fraction(graph))
        if frac >= self.cfg.consolidate_threshold:
            self.consolidate_async()

    def consolidate_async(self, wait=False):
        """Background global consolidation on an MVCC snapshot (device
        mode) or streamed over the disk tier (tiered mode)."""
        if self._backend is not None:
            return self._consolidate_tiered_async(wait)
        with self._state_lock:
            if self._snapshot_n is not None:
                return None  # a version is already in flight: defer
            if self._active_versions >= self.cfg.max_versions:
                return None  # bounded-version policy: defer
            snapshot = self._state
            snap_n = int(snapshot.graph.n)
            self._snapshot_n = snap_n
            self._rev_logs = []
            self._active_versions += 1

        def work():
            consolidated = update.consolidate(snapshot)
            jax.block_until_ready(consolidated.graph.nbrs)
            with self._update_lock, self._state_lock:
                log = mvcc.concat_rev_logs(self._rev_logs)
                merged = mvcc.merge_consolidated(
                    consolidated, self._state,
                    jnp.asarray(snap_n, jnp.int32), log)
                self._state = merged
                self._snapshot_n = None
                self._rev_logs = []
                self._active_versions -= 1
                self._consolidations += 1

        th = threading.Thread(target=work, daemon=True)
        th.start()
        self._bg_threads.append(th)
        if wait:
            th.join()
        return th

    def _consolidate_tiered_async(self, wait=False):
        """MVCC-snapshotted tiered consolidation (paper §5.3 ported to the
        disk tier): freeze topology+alive under the update lock (brief),
        rebuild rows off-lock while inserts/deletes/searches continue on
        the active log, then publish via ``mvcc.merge_consolidated_tiered``
        with the window's reverse-edge log in one short critical section —
        consolidation blocks neither searches nor updates."""
        with self._update_lock:
            with self._state_lock:
                if self._snapshot_n is not None:
                    return None  # a version is already in flight: defer
                if self._active_versions >= self.cfg.max_versions:
                    return None  # bounded-version policy: defer
                self._active_versions += 1
            snap = mvcc.snapshot_tiered(self._backend)
            with self._state_lock:
                self._snapshot_n = snap.n
                self._rev_logs = []

        def work():
            try:
                new_rows = update.consolidate_tiered(
                    self._backend, snapshot=snap)
                with self._update_lock, self._state_lock:
                    # per-batch logs, replayed in order by the merge
                    mvcc.merge_consolidated_tiered(
                        self._backend, snap, new_rows,
                        list(self._rev_logs))
            except walmod.WALWriteError as e:
                # merge not applied (WAL-before-write): degrade to
                # read-only instead of dying silently in the background
                self._degraded = str(e)
            finally:
                with self._state_lock:
                    self._snapshot_n = None
                    self._rev_logs = []
                    self._active_versions -= 1
                    self._consolidations += 1

        th = threading.Thread(target=work, daemon=True)
        th.start()
        self._bg_threads.append(th)
        if wait:
            th.join()
        return th

    def wait_background(self):
        for th in self._bg_threads:
            th.join()
        self._bg_threads = []

    # ------------------------------------------------------------------
    @property
    def state(self) -> IndexState:
        with self._state_lock:
            st = self._state
        if self._backend is not None:
            # tiered mode: the jit-side cache/stats view is materialized
            # on demand from the host mirrors
            with self._cache_lock:
                st = st._replace(cache=self._placement.to_cache_state(),
                                 stats=self._placement.to_stats())
            with self._state_lock:
                self._state = st
        return st

    def stats(self) -> dict:
        st = self.state
        s = st.stats
        d = {k: int(v) for k, v in s._asdict().items()}
        d["miss_rate"] = Cache.miss_rate(s)
        if self._backend is not None:
            d["n"] = int(self._backend.n)
            d["alive"] = int(self._backend.alive[:self._backend.n].sum())
            d.update(self._backend.tier_counts())
            nb = max(self._search_batches, 1)
            d["search_rounds_per_batch"] = self._search_rounds / nb
            d["search_dispatches_per_batch"] = self._search_dispatches / nb
            # single source for the fused-executor acceptance metric: the
            # per-result dispatch counts threaded through
            # TieredSearchResult (coalescing makes a "batch" one device
            # dispatch stream regardless of how many callers it serves)
            d["dispatches_per_query"] = self._search_dispatches / nb
            d["topo_hits"] = self._topo_hits
            d["topo_misses"] = self._topo_misses
            d["topo_hit_rate"] = (self._topo_hits
                                  / max(self._topo_hits
                                        + self._topo_misses, 1))
            d["spec_hits"] = self._spec_hits
            d["spec_misses"] = self._spec_misses
            d["spec_hit_rate"] = (self._spec_hits
                                  / max(self._spec_hits
                                        + self._spec_misses, 1))
            d["spec_rank_resolved"] = self._spec_rank
            if self._spec_probe_us is not None:
                d["spec_probe_us_per_row"] = self._spec_probe_us
            # durability: degraded flag is the graceful-degradation
            # contract (WAL device failed -> read-only, not a crash)
            d["degraded"] = bool(self._degraded)
            d["wal_enabled"] = self._wal is not None
            if self._wal is not None:
                d["wal_last_seq"] = self._wal.last_seq
                d["wal_records"] = self._wal.appended
                d["durable_epoch"] = self._durable_epoch
            # filter lane observability: counts, last routing decision and
            # the selectivity threshold the router compares against
            d["filtered_searches"] = self._filtered_searches
            d["filter_fallbacks"] = self._filter_fallbacks
            d["filter_last_selectivity"] = self._filter_last_selectivity
            d["filter_last_path"] = self._filter_last_path
            d["filter_fallback_selectivity"] = \
                self.cfg.filter_fallback_selectivity
            if self._recovery is not None:
                d["recovered_epoch"] = self._recovery["epoch"]
                d["recovered_replayed"] = self._recovery["replayed"]
                d["recovered_to_seq"] = self._recovery["last_seq"]
                d["recovered_truncated_bytes"] = \
                    self._recovery["truncated_bytes"]
            dim = self._backend.dim
            # per-tier byte footprint: PQ codes give FULL-coverage device
            # distance evaluation in n·m bytes where the exact lane would
            # need n·D·4 device-resident — the acceptance ratio below
            bpt = self._backend.bytes_per_tier()
            bpt["device_exact_cache"] = self._placement.vector_bytes
            d["bytes_per_tier"] = bpt
            n_live = max(int(self._backend.n), 1)
            d["device_exact_equiv_bytes"] = n_live * dim * 4
            if self._backend.pq is not None:
                # TOTAL device vector residency (codes + exact-vector
                # cache); the ratio compares the full-coverage distance
                # lane alone (codes) against its fp32 equivalent — the
                # WAVP cache is identical in both modes and cancels
                d["device_vector_bytes"] = (bpt["device_codes"]
                                            + bpt["device_exact_cache"])
                d["device_footprint_ratio"] = (
                    bpt["device_codes"] / d["device_exact_equiv_bytes"])
                d["pq_m"] = self._backend.pq.m
                d["pq_bits"] = self._backend.pq.bits
                # the EFFECTIVE depth (search_tiered clamps to [k, pool]),
                # not the raw knob — bench entries must record what ran
                sp = self.cfg.search
                d["rerank_depth"] = (sp.pool if self.cfg.rerank_depth <= 0
                                     else max(sp.k, min(self.cfg.rerank_depth,
                                                        sp.pool)))
        else:
            d["n"] = int(st.graph.n)
            d["alive"] = int(st.graph.alive.sum())
            dim = st.graph.vectors.shape[1]
        d["consolidations"] = self._consolidations
        if self._coalescer is not None:
            c = self._coalescer
            d["coalesce_requests"] = c.requests
            d["coalesce_dispatches"] = c.dispatches
            d["coalesce_batch_mean"] = c.queries / max(c.dispatches, 1)
            d["coalesce_window_us"] = c.window * 1e6
            d["coalesce_overshoot_avoided"] = c.tier.overshoot_avoided
            d["degraded_dispatches"] = c.degraded_dispatches
            # SLO serving tier observability: per-tenant p50/p99 (ms),
            # queue depths, shed / deadline-miss counters, pressure and
            # the current degradation level (core/slo.py)
            d["slo"] = c.tier.stats()
        # modeled per-access time on v5e (DESIGN.md §2): this machine has
        # one physical tier, so tier economics are reported via the
        # calibrated cost model applied to observed hit/miss/transfer counts
        from repro.core.calibrate import v5e_constants
        cm = v5e_constants(dim)
        acc = max(d["accesses"], 1)
        modeled = (d["hits"] * cm.t_fast + d["cpu_computed"] * cm.t_slow
                   + d["transfers"] * cm.t_transfer)
        d["modeled_us_per_access"] = modeled / acc * 1e6
        return d

    def close(self):
        """Stop background machinery, publish a final durable epoch (so a
        clean shutdown reopens with zero WAL replay) and flush the disk
        tier (no-op in device mode)."""
        self.wait_background()
        if self._coalescer is not None:
            self._coalescer.stop()
        if self._wal is not None and not self._degraded \
                and not self._wal.closed:
            try:
                self.checkpoint()
            except ReadOnlyEngineError:   # WAL device died at shutdown:
                pass                      # last published epoch still wins
        if self._backend is not None:
            self._backend.close()
        if self._wal is not None:
            self._wal.close()


class MultiStreamRunner:
    """Search/update streams over the engine (the multi-stream analogue):
    search requests flow through the engine's cross-query coalescing
    scheduler — concurrent requests are stacked into one executor
    invocation within the adaptive window and demultiplexed per request —
    plus one dedicated update stream consuming an op queue.
    ``n_search_streams`` bounds the requests concurrently in flight (each
    stream submits one and waits on its future, which is exactly what
    lets the coalescer merge across streams). ``max_batch`` /
    ``batch_timeout`` are kept for API compatibility only — merge depth
    and window now belong to the engine (``coalesce_max_batch`` /
    ``coalesce_window``), which the runner must not mutate: the scheduler
    is shared with every other client of the engine."""

    def __init__(self, engine: SVFusionEngine, n_search_streams=2,
                 max_batch=64, batch_timeout=0.002):
        self.engine = engine
        self.n_search_streams = n_search_streams
        self.max_batch = max_batch
        self.batch_timeout = batch_timeout
        self._q: queue.Queue = queue.Queue()
        self._sq: queue.Queue = queue.Queue()
        self._stop = threading.Event()
        self._threads = []
        self.results: list = []
        self.errors: list = []
        # requests intentionally rejected by the SLO tier (shed /
        # deadline-missed) land here, not in ``errors``: they are the
        # admission policy working as designed, not worker failures
        self.shed: list = []

    def start(self):
        self._threads = [threading.Thread(target=self._update_worker,
                                          daemon=True)]
        for _ in range(self.n_search_streams):
            self._threads.append(threading.Thread(target=self._search_worker,
                                                  daemon=True))
        for t in self._threads:
            t.start()

    def submit_search(self, queries, tag=None, deadline=None):
        """``tag`` doubles as the request's tenant id in the engine's
        SLO admission tier (None -> default tenant); ``deadline`` is
        seconds from dispatch-by-the-worker after which the answer is
        worthless (skip-and-fail admission)."""
        self._sq.put((np.asarray(queries, np.float32), tag, deadline,
                      time.perf_counter()))

    def submit_insert(self, vectors):
        self._q.put(("insert", np.asarray(vectors, np.float32)))

    def submit_delete(self, ids):
        self._q.put(("delete", np.asarray(ids, np.int64)))

    def _search_worker(self):
        while not self._stop.is_set():
            try:
                qarr, tag, deadline, t0 = self._sq.get(timeout=0.05)
            except queue.Empty:
                continue
            try:
                # one in-flight request per stream; the engine's coalescer
                # merges across streams (and any direct submitters)
                ids, _ = self.engine.search(qarr, tenant=tag,
                                            deadline=deadline)
                self.results.append((tag, ids, time.perf_counter() - t0))
            except slo.SLOError as e:
                self.shed.append((tag, e))
            except Exception as e:  # pragma: no cover
                self.errors.append(e)

    def _update_worker(self):
        while not self._stop.is_set():
            try:
                op, payload = self._q.get(timeout=0.05)
            except queue.Empty:
                continue
            try:
                if op == "insert":
                    self.engine.insert(payload)
                else:
                    self.engine.delete(payload)
            except Exception as e:  # pragma: no cover
                self.errors.append(e)

    def drain_and_stop(self, timeout=60.0):
        t0 = time.perf_counter()
        while (not self._sq.empty() or not self._q.empty()) \
                and time.perf_counter() - t0 < timeout:
            time.sleep(0.01)
        self._stop.set()
        for t in self._threads:
            t.join(timeout=5.0)
        if self.errors:
            raise self.errors[0]
