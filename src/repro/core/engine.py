"""Host-side streaming engine: concurrency control + real-time coordination
(paper §4.4, §5.3).

The CUDA multi-stream design maps to host dispatch threads over immutable
jitted programs (DESIGN.md §2): search streams read the last *published*
state snapshot concurrently; a dedicated update stream serializes
insert/delete batches; background consolidation runs on an MVCC snapshot
and merges without blocking foreground traffic.

Consistency guarantees (paper Table 3):
* ``sync=True`` — updates publish atomically under the state lock before
  returning; every subsequent search observes them (read-after-write).
* ``sync=False`` — the ablation: searches read a stale snapshot refreshed
  every ``stale_refresh`` operations, reproducing the paper's
  no-synchronization recall collapse under load.

Also here: adaptive batching (latency/throughput trade, paper Fig. 17),
cold-start warmup (§4.4), deletion-triggered repair/consolidation
scheduling (§5.2), bounded-version policy (§5.3).
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cache as Cache
from repro.core import mvcc, update
from repro.core.build import build_index
from repro.core.search import search_batch
from repro.core.types import IndexState, SearchParams


@dataclass
class EngineConfig:
    degree: int = 32
    cache_slots: int = 4096
    capacity: int = 1 << 16
    search: SearchParams = field(default_factory=SearchParams)
    repair_every: int = 8          # update batches between repair scans
    repair_budget: int = 256
    consolidate_threshold: float = 0.2   # paper: 20% deleted
    repair_threshold: float = 0.5        # paper: >50% dead neighbors
    max_versions: int = 2                # bounded-version policy
    sync: bool = True
    stale_refresh: int = 64              # ops between refreshes when !sync
    seed: int = 0


class SVFusionEngine:
    """Thread-safe streaming SANNS engine over the functional core."""

    def __init__(self, init_vectors, cfg: EngineConfig):
        self.cfg = cfg
        self._key = jax.random.PRNGKey(cfg.seed)
        self._state_lock = threading.RLock()   # publish/subscribe
        self._update_lock = threading.Lock()   # serializes the update stream
        self._cache_lock = threading.Lock()
        self._state = build_index(
            np.asarray(init_vectors, np.float32), degree=cfg.degree,
            cache_slots=cfg.cache_slots, n_max=cfg.capacity)
        self._stale_state = self._state
        self._ops_since_refresh = 0
        self._update_batches = 0
        self._consolidations = 0
        self._active_versions = 0
        self._rev_logs: list = []
        self._snapshot_n: Optional[int] = None
        self._bg_threads: list = []
        self.latencies: dict[str, list] = {"search": [], "insert": [],
                                           "delete": []}

    # ------------------------------------------------------------------
    def _next_key(self):
        with self._cache_lock:
            self._key, sub = jax.random.split(self._key)
        return sub

    def _read_state(self) -> IndexState:
        if self.cfg.sync:
            with self._state_lock:
                return self._state
        # no-sync ablation: stale snapshot, periodically refreshed
        self._ops_since_refresh += 1
        if self._ops_since_refresh >= self.cfg.stale_refresh:
            self._ops_since_refresh = 0
            with self._state_lock:
                self._stale_state = self._state
        return self._stale_state

    def _publish(self, state: IndexState):
        with self._state_lock:
            self._state = state

    # ------------------------------------------------------------------
    def search(self, queries, update_cache=True):
        """Batched search. Returns (ids, dists) as numpy. Batches are padded
        to power-of-two buckets to bound the number of jit specializations."""
        t0 = time.perf_counter()
        st = self._read_state()
        queries = jnp.asarray(queries, jnp.float32)
        B = queries.shape[0]
        Bp = 1 << max(0, (B - 1)).bit_length()
        if Bp != B:
            queries = jnp.concatenate(
                [queries, jnp.zeros((Bp - B, queries.shape[1]), queries.dtype)])
        res = search_batch(st, queries, self._next_key(), self.cfg.search)
        if Bp != B:
            lane = jnp.arange(Bp)[:, None] < B   # mask pad lanes out of logs
            res = res._replace(ids=res.ids[:B], dists=res.dists[:B],
                               acc_ids=jnp.where(lane, res.acc_ids, -1),
                               acc_hit=res.acc_hit & lane)
        ids = np.asarray(res.ids)
        if update_cache:
            # cache placement is applied to the *current* state (the cache
            # tier is shared; graph fields pass through untouched)
            with self._state_lock:
                cur = self._state
                new = Cache.apply_wavp(cur._replace(cache=cur.cache),
                                       res.acc_ids, res.acc_hit,
                                       self.cfg.search,
                                       now=self._update_batches)
                self._state = cur._replace(cache=new.cache, stats=new.stats)
        self.latencies["search"].append(time.perf_counter() - t0)
        return ids, np.asarray(res.dists)

    def insert(self, vectors, chunk=512):
        """Insert vectors (chunked so each chunk links into the graph the
        previous chunks built; a near-empty index is bootstrapped with an
        exact KNN stitch among the first chunk)."""
        t0 = time.perf_counter()
        vectors = np.asarray(vectors, np.float32)
        out = []
        with self._update_lock:
            for s in range(0, len(vectors), chunk):
                part = jnp.asarray(vectors[s:s + chunk])
                st = self._state
                if int(st.graph.alive.sum()) < 2 * self.cfg.degree:
                    st2, ids = self._bootstrap_insert(st, part)
                    rev = None
                else:
                    st2, ids, rev = update.insert_batch(
                        st, part, self._next_key(), self.cfg.search)
                if rev is not None and self._snapshot_n is not None:
                    self._rev_logs.append(rev)
                self._publish(st2)
                self._update_batches += 1
                out.append(np.asarray(ids))
        self._maybe_maintain()
        self.latencies["insert"].append(time.perf_counter() - t0)
        return np.concatenate(out)

    def _bootstrap_insert(self, st, part):
        """Exact-KNN stitch for a (near-)empty index."""
        from repro.core.build import _exact_knn, compute_e_in
        g = st.graph
        n0 = int(g.n)
        bi = part.shape[0]
        ids = n0 + jnp.arange(bi, dtype=jnp.int32)
        vectors = g.vectors.at[ids].set(part)
        alive = g.alive.at[ids].set(True)
        live_ids = np.where(np.asarray(alive[:n0 + bi]))[0]
        sub = vectors[jnp.asarray(live_ids)]
        knn = _exact_knn(sub, min(g.degree, max(1, len(live_ids) - 1)))
        rows = jnp.asarray(live_ids)[jnp.clip(knn, 0)]
        rows = jnp.where(knn >= 0, rows, -1)
        pad = g.degree - rows.shape[1]
        if pad > 0:
            rows = jnp.concatenate(
                [rows, jnp.full((rows.shape[0], pad), -1, jnp.int32)], 1)
        nbrs = g.nbrs.at[jnp.asarray(live_ids)].set(rows.astype(jnp.int32))
        g = g._replace(vectors=vectors, alive=alive, nbrs=nbrs,
                       n=jnp.asarray(n0 + bi, jnp.int32))
        g = g._replace(e_in=compute_e_in(g.nbrs, g.capacity))
        return st._replace(graph=g), ids

    def delete(self, ids):
        t0 = time.perf_counter()
        with self._update_lock:
            st2 = update.delete_batch(self._state,
                                      jnp.asarray(ids, jnp.int32))
            self._publish(st2)
            self._update_batches += 1
        self._maybe_maintain()
        self.latencies["delete"].append(time.perf_counter() - t0)

    # ------------------------------------------------------------------
    def _maybe_maintain(self):
        if self._update_batches % self.cfg.repair_every == 0:
            with self._update_lock:
                st, nrep = update.repair_affected(
                    self._state, max_repair=self.cfg.repair_budget,
                    threshold=self.cfg.repair_threshold)
                self._publish(st)
        frac = float(update.deleted_fraction(self._state.graph))
        if frac >= self.cfg.consolidate_threshold:
            self.consolidate_async()

    def consolidate_async(self, wait=False):
        """Background global consolidation on an MVCC snapshot."""
        with self._state_lock:
            if self._snapshot_n is not None:
                return None  # a version is already in flight: defer
            if self._active_versions >= self.cfg.max_versions:
                return None  # bounded-version policy: defer
            snapshot = self._state
            snap_n = int(snapshot.graph.n)
            self._snapshot_n = snap_n
            self._rev_logs = []
            self._active_versions += 1

        def work():
            consolidated = update.consolidate(snapshot)
            jax.block_until_ready(consolidated.graph.nbrs)
            with self._update_lock, self._state_lock:
                log = mvcc.concat_rev_logs(self._rev_logs)
                merged = mvcc.merge_consolidated(
                    consolidated, self._state,
                    jnp.asarray(snap_n, jnp.int32), log)
                self._state = merged
                self._snapshot_n = None
                self._rev_logs = []
                self._active_versions -= 1
                self._consolidations += 1

        th = threading.Thread(target=work, daemon=True)
        th.start()
        self._bg_threads.append(th)
        if wait:
            th.join()
        return th

    def wait_background(self):
        for th in self._bg_threads:
            th.join()
        self._bg_threads = []

    # ------------------------------------------------------------------
    @property
    def state(self) -> IndexState:
        with self._state_lock:
            return self._state

    def stats(self) -> dict:
        s = self.state.stats
        d = {k: int(v) for k, v in s._asdict().items()}
        d["miss_rate"] = Cache.miss_rate(s)
        d["n"] = int(self.state.graph.n)
        d["alive"] = int(self.state.graph.alive.sum())
        d["consolidations"] = self._consolidations
        # modeled per-access time on v5e (DESIGN.md §2): this machine has
        # one physical tier, so tier economics are reported via the
        # calibrated cost model applied to observed hit/miss/transfer counts
        from repro.core.calibrate import v5e_constants
        cm = v5e_constants(self.state.graph.vectors.shape[1])
        acc = max(d["accesses"], 1)
        modeled = (d["hits"] * cm.t_fast + d["cpu_computed"] * cm.t_slow
                   + d["transfers"] * cm.t_transfer)
        d["modeled_us_per_access"] = modeled / acc * 1e6
        return d


class MultiStreamRunner:
    """Search/update streams over the engine (the multi-stream analogue):
    N search worker threads + one dedicated update stream consuming an op
    queue with adaptive batching."""

    def __init__(self, engine: SVFusionEngine, n_search_streams=2,
                 max_batch=64, batch_timeout=0.002):
        self.engine = engine
        self.n_search_streams = n_search_streams
        self.max_batch = max_batch
        self.batch_timeout = batch_timeout
        self._q: queue.Queue = queue.Queue()
        self._sq: queue.Queue = queue.Queue()
        self._stop = threading.Event()
        self._threads = []
        self.results: list = []
        self.errors: list = []

    def start(self):
        self._threads = [threading.Thread(target=self._update_worker,
                                          daemon=True)]
        for _ in range(self.n_search_streams):
            self._threads.append(threading.Thread(target=self._search_worker,
                                                  daemon=True))
        for t in self._threads:
            t.start()

    def submit_search(self, queries, tag=None):
        self._sq.put((np.asarray(queries, np.float32), tag, time.perf_counter()))

    def submit_insert(self, vectors):
        self._q.put(("insert", np.asarray(vectors, np.float32)))

    def submit_delete(self, ids):
        self._q.put(("delete", np.asarray(ids, np.int64)))

    def _drain(self, q, first):
        """Adaptive batching: collect up to max_batch items within timeout."""
        items = [first]
        deadline = time.perf_counter() + self.batch_timeout
        while len(items) < self.max_batch:
            try:
                items.append(q.get(timeout=max(0.0, deadline - time.perf_counter())))
            except queue.Empty:
                break
        return items

    def _search_worker(self):
        while not self._stop.is_set():
            try:
                first = self._sq.get(timeout=0.05)
            except queue.Empty:
                continue
            items = self._drain(self._sq, first)
            try:
                qs = np.concatenate([i[0] for i in items], axis=0)
                ids, dists = self.engine.search(qs)
                off = 0
                for qarr, tag, t0 in items:
                    b = qarr.shape[0]
                    self.results.append((tag, ids[off:off + b],
                                         time.perf_counter() - t0))
                    off += b
            except Exception as e:  # pragma: no cover
                self.errors.append(e)

    def _update_worker(self):
        while not self._stop.is_set():
            try:
                op, payload = self._q.get(timeout=0.05)
            except queue.Empty:
                continue
            try:
                if op == "insert":
                    self.engine.insert(payload)
                else:
                    self.engine.delete(payload)
            except Exception as e:  # pragma: no cover
                self.errors.append(e)

    def drain_and_stop(self, timeout=60.0):
        t0 = time.perf_counter()
        while (not self._sq.empty() or not self._q.empty()) \
                and time.perf_counter() - t0 < timeout:
            time.sleep(0.01)
        self._stop.set()
        for t in self._threads:
            t.join(timeout=5.0)
        if self.errors:
            raise self.errors[0]
