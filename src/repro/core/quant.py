"""Product quantization for the device-resident code lane (FusionANNS-style
coarse-then-refine, PAPERS.md): the paper's whole machinery (WAVP caching,
cascading lookup, speculation) works around exact fp32 vectors not fitting
on the device — the complementary move is to keep *compressed* PQ codes
unconditionally device-resident and score every candidate there with an
asymmetric-distance (ADC) lookup-table scan, fetching exact vectors through
the tier cascade only for a small re-rank set.

Layout: D dims split into ``m`` contiguous subspaces of ``dsub = D/m``
dims; each subspace has its own ``K = 2**bits`` Lloyd/k-means codebook.
A vector encodes to ``m`` uint8 codes — at m=16, bits=8 that is D·4/16
times smaller than fp32 (32x at D=128), so datasets far larger than the
device cache get full-coverage device-side distance evaluation.

ADC: per query, ``adc_lut`` precomputes ``lut[s, k] = ||q_s − c_sk||²``
once ([m, K] floats); a candidate's distance is then ``Σ_s lut[s,
code[x, s]]`` — a gather + reduce, no FLOPs on the vector itself (the
``kernels/pq_adc`` pair runs it over the executor's (Q, beam·R) id
matrix with the same in-kernel invalid-lane masking as ``l2_gather``).

``PQCodes`` is the serving-side lane state: host-truth codes array with
write-through incremental encoding for streamed inserts
(``update.insert_tiered``) and an epoch-synced device mirror searches
read lock-free. Codebooks are trained once at index time on a sample and
frozen; streamed vectors are encoded against the frozen codebooks, the
standard PQ serving regime.
"""
from __future__ import annotations

import threading
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class PQCodebook(NamedTuple):
    """Per-subspace centroid tables."""
    centroids: jax.Array     # [m, K, dsub] float32

    @property
    def m(self) -> int:
        return self.centroids.shape[0]

    @property
    def n_codes(self) -> int:
        return self.centroids.shape[1]

    @property
    def dsub(self) -> int:
        return self.centroids.shape[2]

    @property
    def dim(self) -> int:
        return self.m * self.dsub


def choose_m(dim: int, m: int) -> int:
    """Largest divisor of ``dim`` that is <= the requested subspace count
    (PQ needs D % m == 0; the engine degrades gracefully instead of
    refusing a dataset whose dim the knob doesn't divide)."""
    m = max(1, min(m, dim))
    while dim % m:
        m -= 1
    return m


def _sqdist_to_centroids(sub, cents):
    """Per-subspace squared distances, the ONE expansion all three PQ
    primitives share (train assignment, encode argmin, ADC LUT — they
    must agree numerically for ADC distances to mean anything):
    sub [..., m, dsub] vs cents [m, K, dsub] -> [..., m, K]."""
    return (jnp.sum(sub * sub, -1)[..., None]
            - 2.0 * jnp.einsum("...md,mkd->...mk", sub, cents,
                               preferred_element_type=jnp.float32)
            + jnp.sum(cents * cents, -1))


@partial(jax.jit, static_argnames=("m", "k", "iters"))
def _train(vectors, key, m: int, k: int, iters: int):
    """Lloyd's k-means, vectorized over the m subspaces (one [n, m, K]
    assignment tensor per sweep; callers bound n by sampling)."""
    n, D = vectors.shape
    dsub = D // m
    sub = vectors.reshape(n, m, dsub)                          # [n, m, dsub]
    perm = jax.random.permutation(key, n)
    init = sub[perm[jnp.arange(k) % n]].transpose(1, 0, 2)     # [m, k, dsub]

    def step(c, _):
        d = _sqdist_to_centroids(sub, c)                       # [n, m, k]
        assign = jnp.argmin(d, -1)                             # [n, m]
        onehot = jax.nn.one_hot(assign, k, dtype=jnp.float32)  # [n, m, k]
        cnt = onehot.sum(0)                                    # [m, k]
        sums = jnp.einsum("nmk,nmd->mkd", onehot, sub,
                          preferred_element_type=jnp.float32)
        # empty clusters keep their old centroid (never NaN-divide)
        new = jnp.where(cnt[..., None] > 0,
                        sums / jnp.maximum(cnt, 1.0)[..., None], c)
        return new, None

    c, _ = jax.lax.scan(step, init, None, length=iters)
    return c


def train_codebook(vectors, m: int, bits: int, *, iters: int = 20,
                   sample: int = 4096, seed: int = 0) -> PQCodebook:
    """Train per-subspace codebooks on (a sample of) the dataset.
    bits <= 8 so codes stay uint8 (the whole point of the lane)."""
    if not 1 <= bits <= 8:
        raise ValueError(f"pq bits must be in [1, 8], got {bits}")
    vectors = np.asarray(vectors, np.float32)
    n, D = vectors.shape
    if D % m:
        raise ValueError(f"dim {D} not divisible by m={m} "
                         f"(use choose_m to pick a divisor)")
    if sample and n > sample:
        idx = np.random.default_rng(seed).choice(n, sample, replace=False)
        vectors = vectors[np.sort(idx)]
    k = 1 << bits
    cents = _train(jnp.asarray(vectors), jax.random.PRNGKey(seed),
                   m, k, iters)
    return PQCodebook(centroids=cents)


@jax.jit
def _encode(centroids, vectors):
    m, k, dsub = centroids.shape
    n = vectors.shape[0]
    sub = vectors.reshape(n, m, dsub)
    return jnp.argmin(_sqdist_to_centroids(sub, centroids),
                      -1).astype(jnp.uint8)


def encode(codebook: PQCodebook, vectors, chunk: int = 4096) -> np.ndarray:
    """Vectors [n, D] -> codes [n, m] uint8. Chunked (padded to the chunk
    size so the jitted body compiles once) to bound the [chunk, m, K]
    assignment tensor at index-time scale."""
    vectors = np.asarray(vectors, np.float32)
    n = vectors.shape[0]
    out = np.empty((n, codebook.m), np.uint8)
    for s in range(0, n, chunk):
        part = vectors[s:s + chunk]
        pad = chunk - len(part)
        if pad > 0 and n > chunk:   # keep the single compiled shape
            part = np.concatenate(
                [part, np.zeros((pad, vectors.shape[1]), np.float32)])
        out[s:s + chunk] = np.asarray(
            _encode(codebook.centroids, jnp.asarray(part)))[:min(chunk,
                                                                 n - s)]
    return out


def codebook_to_array(codebook: PQCodebook) -> np.ndarray:
    """Host array form of the frozen centroid tables, for the durability
    snapshot (``wal.publish_snapshot``)."""
    return np.asarray(codebook.centroids, np.float32)


def codebook_from_array(centroids: np.ndarray) -> PQCodebook:
    """Rebuild the codebook from a persisted centroid array. Encoding is
    deterministic given the centroids, so replayed inserts re-encode to
    the same codes the crashed run wrote."""
    return PQCodebook(centroids=jnp.asarray(centroids, jnp.float32))


def decode(codebook: PQCodebook, codes) -> np.ndarray:
    """Codes [n, m] -> reconstructed vectors [n, D] float32."""
    codes = np.asarray(codes)
    cents = np.asarray(codebook.centroids)                    # [m, K, dsub]
    n, m = codes.shape
    out = cents[np.arange(m)[None, :], codes.astype(np.int64)]  # [n, m, dsub]
    return out.reshape(n, m * cents.shape[2]).astype(np.float32)


@jax.jit
def adc_lut(centroids, queries):
    """Per-query ADC lookup tables: queries [B, D] -> lut [B, m, K] with
    ``lut[b, s, k] = ||q_sub[b, s] − centroids[s, k]||²``."""
    m, k, dsub = centroids.shape
    B = queries.shape[0]
    qs = queries.astype(jnp.float32).reshape(B, m, dsub)
    return _sqdist_to_centroids(qs, centroids)


class PQCodes:
    """Serving-side PQ lane state: frozen codebook + unconditionally
    resident codes (host truth + device mirror).

    Unlike exact vectors — whose device residency WAVP has to ration —
    codes are ~D·4/m times smaller, so the WHOLE id space stays device-
    resident and every executor round scores all candidates on device.

    Write-through: the update stream encodes inserted vectors against the
    frozen codebook (``encode_write``; ``update.insert_tiered`` calls it)
    into the host array and logs the dirty block; searches call
    ``synced_codes()`` which folds pending blocks into the device mirror
    under a lock and returns the (immutable) device array — readers are
    never torn, at worst one-update-batch stale, exactly the alive/e_in
    directory consistency model."""

    def __init__(self, codebook: PQCodebook, capacity: int,
                 codes: np.ndarray = None):
        self.codebook = codebook
        self.codes = np.zeros((capacity, codebook.m), np.uint8)
        if codes is not None:
            self.codes[:len(codes)] = codes
        self._codes_j = jnp.asarray(self.codes)
        self._dirty: list = []
        self._lock = threading.Lock()
        self.encoded = 0          # rows encoded incrementally (stats)

    @property
    def m(self) -> int:
        return self.codebook.m

    @property
    def bits(self) -> int:
        return int(self.codebook.n_codes - 1).bit_length()

    def encode_write(self, ids, vectors) -> np.ndarray:
        """Incremental write-through encode (update stream only)."""
        c = encode(self.codebook, vectors)
        ids = np.asarray(ids)
        with self._lock:
            self.codes[ids] = c
            self._dirty.append(ids.copy())
            self.encoded += len(ids)
        return c

    def synced_codes(self) -> jax.Array:
        """Device mirror with all pending write-through blocks applied —
        folded in ONE scatter (each ``.at[].set`` copies the whole
        device array, so per-block application would cost one full copy
        per insert batch since the last search)."""
        with self._lock:
            if self._dirty:
                ids = np.unique(np.concatenate(self._dirty))
                self._codes_j = self._codes_j.at[ids].set(self.codes[ids])
                self._dirty.clear()
            return self._codes_j

    def snapshot(self, n: int) -> np.ndarray:
        """Consistent copy of the host-truth codes over [0, n) for the
        durability snapshot — taken under the write-through lock so a
        concurrent ``encode_write`` can never tear the cut."""
        with self._lock:
            return self.codes[:n].copy()

    def code_bytes(self, n: int = None) -> int:
        """Device-resident code footprint (bytes) over ``n`` ids (whole
        array when None)."""
        if n is None:
            return self.codes.nbytes
        return int(n) * self.codes.shape[1] * self.codes.itemsize
