"""Workload-Aware Vector Placement (paper §4.3, Algorithm 2) + baselines.

The prediction function F_λ(x) = α·F_recent(x,t) + β·log(1+E_in(x)) reduces
the gain test gain(x) > 0 to the threshold test F_λ(x) > θ with
θ = T_transfer/(T_CPU − T_GPU) (paper's theoretical analysis). Placement is
applied once per search batch with transfers amortized over the batch
(paper: 2048-vector transfer batches).

Eviction is the paper's clock-sweep with predicted-frequency tie-break,
*vectorized* for the TPU (DESIGN.md §2): empty slots are used first, then
slots with reference bit 0 in ascending F_λ; ref bits are refreshed by the
batch's cache hits (one sweep per batch). An exact sequential clock lives in
``clock_reference.py`` as the semantics oracle for tests.

Baseline policies (paper §6.3): LRU, LFU, LRFU, ``never`` (w/o WAVP — always
compute misses on the capacity tier), ``always`` (promote every miss).
"""
from __future__ import annotations

import threading
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import CacheState, GraphState, IndexState, SearchParams, Stats


def f_lambda(cache: CacheState, graph: GraphState):
    """F_λ(x) = α·F_recent + β·log(1+E_in) (paper eq. 2)."""
    return (cache.alpha * cache.f_recent
            + cache.beta * jnp.log1p(graph.e_in.astype(jnp.float32)))


def f_lambda_np(f_recent, e_in, alpha=1.0, beta=1.0):
    """Host-side F_λ over numpy mirrors — the SAME predictor drives both
    ends of the hierarchy: device-cache promotion (here / apply_wavp) and
    host-window demotion order in ``tiers.TieredStore`` (paper §4.3)."""
    return (np.float32(alpha) * np.asarray(f_recent, np.float32)
            + np.float32(beta) * np.log1p(np.asarray(e_in, np.float32)))


def _policy_scores(policy, cache, graph):
    """Higher score = more worth caching. f_recent holds the policy's own
    statistic: timestamps for LRU, raw counts for LFU, decayed counts (CRF)
    for LRFU/WAVP."""
    if policy in ("wavp", "always"):
        return f_lambda(cache, graph)
    return cache.f_recent


@partial(jax.jit, static_argnames=("sp",))
def apply_wavp(state: IndexState, acc_ids, acc_hit, sp: SearchParams,
               now=0) -> IndexState:
    """Post-batch placement pass (Algorithm 2, batched).

    acc_ids [B, rounds·beam·R] accessed ids (-1 pad) from the frontier
    executor's round logs, acc_hit [B, rounds·beam·R] hit flags.
    """
    graph, cache, stats = state.graph, state.cache, state.stats
    N = graph.capacity
    M = cache.n_slots

    ids = acc_ids.reshape(-1)
    hit = acc_hit.reshape(-1)
    valid = ids >= 0
    cid = jnp.clip(ids, 0)

    counts = jnp.zeros((N,), jnp.float32).at[cid].add(valid.astype(jnp.float32))
    miss_counts = jnp.zeros((N,), jnp.float32).at[cid].add(
        (valid & ~hit).astype(jnp.float32))

    if sp.policy == "lru":
        f_recent = jnp.where(counts > 0, jnp.float32(now) + 1.0,
                             cache.f_recent)
    else:
        decay = jnp.float32(1.0 if sp.policy == "lfu" else sp.decay)
        f_recent = cache.f_recent * decay + counts
    cache = cache._replace(f_recent=f_recent)

    n_acc = valid.sum()
    n_hit = (valid & hit).sum()
    stats = stats._replace(
        accesses=stats.accesses + n_acc.astype(jnp.int32),
        hits=stats.hits + n_hit.astype(jnp.int32),
        misses=stats.misses + (n_acc - n_hit).astype(jnp.int32),
    )

    if sp.policy == "never":
        # w/o WAVP: all misses computed in place on the capacity tier
        stats = stats._replace(cpu_computed=stats.cpu_computed
                               + (n_acc - n_hit).astype(jnp.int32))
        return IndexState(graph, cache, stats)

    score = _policy_scores(sp.policy, cache, graph)

    # ---- selective prefetch (Alg. 2 lines 1-2): F_λ(x) > θ to promote ----
    thr = cache.theta if sp.policy == "wavp" else jnp.float32(-jnp.inf)
    cand_mask = (miss_counts > 0) & (cache.h2d < 0) & graph.alive \
        & (score > thr)
    cand_score = jnp.where(cand_mask, score, -jnp.inf)
    P = min(sp.max_promote, M)
    prom_score, prom_ids = jax.lax.top_k(cand_score, P)
    prom_valid = jnp.isfinite(prom_score)

    # ---- predictive replacement (Alg. 2 lines 3-11), vectorized clock ----
    occ_score = jnp.where(cache.slot_hid >= 0,
                          score[jnp.clip(cache.slot_hid, 0)], -jnp.inf)
    # eviction priority: empty slots first, then ref==0 by ascending F_λ;
    # ref==1 slots are protected this sweep (second chance).
    empty = cache.slot_hid < 0
    protected = (cache.ref > 0) & ~empty
    evict_key = jnp.where(empty, -jnp.inf,
                          jnp.where(protected, jnp.inf, occ_score))
    victim_order = jnp.argsort(evict_key)
    victims = victim_order[:P]
    victim_ok = ~protected[victims]
    # only evict a victim whose score is lower than the incomer's
    improves = prom_valid & victim_ok & (
        (evict_key[victims] < prom_score) | empty[victims])

    vslot = jnp.where(improves, victims, M)        # M = scatter no-op row
    old_hid = jnp.where(improves, cache.slot_hid[jnp.clip(victims, 0)], -1)
    new_hid = jnp.where(improves, prom_ids, -1)

    h2d = cache.h2d.at[jnp.clip(old_hid, 0)].set(
        jnp.where(old_hid >= 0, -1, cache.h2d[jnp.clip(old_hid, 0)]))
    h2d = h2d.at[jnp.clip(new_hid, 0)].set(
        jnp.where(new_hid >= 0, vslot.astype(jnp.int32),
                  h2d[jnp.clip(new_hid, 0)]))

    slot_hid = jnp.concatenate([cache.slot_hid, jnp.full((1,), -1, jnp.int32)])
    slot_hid = slot_hid.at[vslot].set(jnp.where(improves, new_hid, -1))[:M]
    # pad row carries the cache dtype: a default-fp32 pad would silently
    # promote a bf16 bandwidth tier to fp32 (2x device-cache memory)
    vec_pad = jnp.concatenate(
        [cache.vectors,
         jnp.zeros((1, cache.vectors.shape[1]), cache.vectors.dtype)], 0)
    vec_pad = vec_pad.at[vslot].set(
        graph.vectors[jnp.clip(new_hid, 0)].astype(cache.vectors.dtype))
    vectors = vec_pad[:M]
    ver_pad = jnp.concatenate([cache.slot_ver, jnp.zeros((1,), jnp.int32)])
    ver_pad = ver_pad.at[vslot].set(graph.version[jnp.clip(new_hid, 0)])

    # clock ref refresh: slots hit this batch get a second chance
    hit_slot = jnp.where(valid & hit, cache.h2d[cid], -1)
    ref = jnp.zeros((M + 1,), jnp.int8).at[jnp.clip(hit_slot, 0)].set(
        jnp.int8(1))
    ref = ref.at[vslot].set(jnp.int8(1))[:M]       # fresh entries referenced

    n_prom = improves.sum().astype(jnp.int32)
    n_evict = (improves & (old_hid >= 0)).sum().astype(jnp.int32)
    cache = cache._replace(vectors=vectors, slot_hid=slot_hid, h2d=h2d,
                           ref=ref, slot_ver=ver_pad[:M])

    # ---- θ adaptation (paper §4.4): more selective when misses rise with
    # high predicted demand ----
    if sp.policy == "wavp":
        miss_rate = (n_acc - n_hit) / jnp.maximum(n_acc, 1)
        mean_f = jnp.where(cand_mask, score, 0.0).sum() / jnp.maximum(
            cand_mask.sum(), 1)
        pressure = miss_rate * mean_f
        theta = jnp.clip(cache.theta * 0.95 + 0.05 * pressure, 1e-3, 1e6)
        cache = cache._replace(theta=theta)

    stats = stats._replace(
        promotions=stats.promotions + n_prom,
        evictions=stats.evictions + n_evict,
        transfers=stats.transfers + n_prom,
        cpu_computed=stats.cpu_computed
        + (n_acc - n_hit).astype(jnp.int32) - n_prom)
    return IndexState(graph, cache, stats)


def miss_rate(stats: Stats) -> float:
    a = max(int(stats.accesses), 1)
    return float(stats.misses) / a


# ---------------------------------------------------------------------------
# Host-side placement for the tiered (disk-backed) engine
# ---------------------------------------------------------------------------

class CacheView(NamedTuple):
    """Immutable (h2d, vectors) pair readers resolve device hits against.
    Published as ONE attribute store so a concurrent placement pass can
    never pair an old mapping with new payloads (torn read)."""
    h2d: np.ndarray
    vectors: np.ndarray


class HostPlacement:
    """Numpy mirror of CacheState + Stats for the three-tier engine.

    When the capacity tier lives behind a ``TieredStore`` the placement
    pass cannot run inside jit (promoted payloads may need a disk read),
    so the engine keeps the bandwidth-tier bookkeeping in host arrays and
    runs Algorithm 2 here with identical semantics to ``apply_wavp``.
    Readers (search threads) take ``self.view`` once — a single immutable
    snapshot — without the engine's cache lock; the update pass builds
    fresh arrays and publishes them through one ``view`` assignment, so a
    concurrent reader sees a consistent (possibly one-batch stale) pair.

    Division of labor with the PQ code lane (``quant.PQCodes``): WAVP
    manages EXACT-vector slots only — the scarce fp32 payload the
    re-rank stage reads. PQ codes are ~D·4/m times smaller and therefore
    unconditionally device-resident; they never compete for these slots
    and never appear in the placement pass.
    """

    def __init__(self, n_ids: int, n_slots: int, dim: int, *, theta=1.0,
                 alpha=1.0, beta=1.0, dtype=np.float32):
        self.vectors = np.zeros((n_slots, dim), dtype)
        self.slot_hid = np.full((n_slots,), -1, np.int32)
        self.h2d = np.full((n_ids,), -1, np.int32)
        self.ref = np.zeros((n_slots,), np.int8)
        self.slot_ver = np.zeros((n_slots,), np.int32)
        self.f_recent = np.zeros((n_ids,), np.float32)
        self.theta = float(theta)
        self.alpha = float(alpha)
        self.beta = float(beta)
        self.counters = {f: 0 for f in Stats._fields}
        self.view = CacheView(self.h2d, self.vectors)

    @property
    def n_slots(self) -> int:
        return self.vectors.shape[0]

    @property
    def vector_bytes(self) -> int:
        """Device-resident exact-vector payload (the WAVP-managed slots;
        per-tier footprint reporting in ``engine.stats()``)."""
        return int(self.vectors.nbytes)

    def scores(self, e_in):
        return f_lambda_np(self.f_recent, e_in, self.alpha, self.beta)

    def warm(self, ids, vectors):
        """Cold-start preload (paper §4.4): fill slots [0, len(ids))."""
        m = min(len(ids), self.n_slots)
        sl = np.arange(m, dtype=np.int32)
        self.vectors[sl] = np.asarray(vectors[:m], self.vectors.dtype)
        self.slot_hid[sl] = np.asarray(ids[:m], np.int32)
        self.h2d[np.asarray(ids[:m])] = sl
        self.view = CacheView(self.h2d, self.vectors)

    def to_cache_state(self) -> CacheState:
        """Materialize the jit-side CacheState view (for engine.state)."""
        return CacheState(
            vectors=jnp.asarray(self.vectors),
            slot_hid=jnp.asarray(self.slot_hid),
            h2d=jnp.asarray(self.h2d),
            ref=jnp.asarray(self.ref),
            slot_ver=jnp.asarray(self.slot_ver),
            f_recent=jnp.asarray(self.f_recent),
            theta=jnp.asarray(self.theta, jnp.float32),
            alpha=jnp.asarray(self.alpha, jnp.float32),
            beta=jnp.asarray(self.beta, jnp.float32),
        )

    def to_stats(self) -> Stats:
        return Stats(*(jnp.asarray(self.counters[f], jnp.int32)
                       for f in Stats._fields))


class TopoCache:
    """Device-resident topology tier: a row-slot lane caching the hot
    subgraph's adjacency rows next to (not inside) the exact-vector
    cache, so the fused multi-round executor can walk the graph without
    a host round-trip per round (FusionANNS-style device-resident coarse
    structure: rows are degree·4 bytes/id vs dim·4 for a vector).

    Residency is ordered by the SAME WAVP F_λ predictor that manages the
    exact-vector slots: admission is demand-driven (the fused shell
    installs the frontier's missing rows before re-entering the loop) and
    eviction takes the lowest-F_λ residents first, with the current
    frontier protected so an install can never thrash the very rows the
    next dispatch needs.

    Write fencing mirrors ``_StageMap``: ``validate`` snapshots the
    store's write epoch and, when it moves (``update.insert_tiered``
    writes rows through ``TieredStore.write``), invalidates the cached
    topology wholesale — every resident row is re-read from the store in
    one bulk ``peek_rows`` and the device mirror republished, so a served
    row is never staler than the per-round path's demand fetch. Re-reading
    (rather than emptying) keeps the residency set, which is what keeps
    dispatches/query low across the interleaved insert batches of the
    streaming bench.

    Host arrays are the truth; ``synced`` publishes the device mirror
    (full re-put on change — installs are batched, so this is one
    transfer per host re-entry at worst). All mutation happens under one
    lock: concurrent search shells may install/validate concurrently.
    """

    def __init__(self, capacity: int, slots: int, degree: int):
        self.capacity = int(capacity)
        self.slots = int(slots)
        self.degree = int(degree)
        self.rows = np.full((max(self.slots, 1), degree), -1, np.int32)
        self.slot_hid = np.full((max(self.slots, 1),), -1, np.int64)
        self.h2s = np.full((capacity,), -1, np.int32)
        self.epoch = None            # set on first validate()
        self.hits = 0                # frontier ids found resident
        self.misses = 0              # frontier ids needing a delta fetch
        self.installs = 0
        self.evictions = 0
        self.flushes = 0             # epoch-fence wholesale refreshes
        self._cursor = 0             # slots allotted once, like TieredStore
        self._dirty = True
        self._rows_j = None
        self._h2s_j = None
        self._lock = threading.Lock()

    @property
    def row_bytes(self) -> int:
        """Device-resident topology payload (bytes_per_tier reporting)."""
        return int(self.rows.nbytes + self.h2s.nbytes) if self.slots else 0

    @property
    def resident(self) -> int:
        return int((self.slot_hid >= 0).sum())

    @property
    def hit_rate(self) -> float:
        t = self.hits + self.misses
        return self.hits / t if t else 0.0

    def validate(self, store) -> None:
        """Epoch fence: when the store's write epoch moved, re-read every
        resident row wholesale (one bulk peek) and republish."""
        ep = store.write_epoch
        with self._lock:
            if self.epoch is None:
                self.epoch = ep
                return
            if ep == self.epoch:
                return
            occ = self.slot_hid >= 0
            if occ.any():
                self.rows[occ] = store.peek_rows(self.slot_hid[occ])
                self._dirty = True
            self.epoch = ep
            self.flushes += 1

    def install(self, ids, rows, f_lam=None, protect=None) -> bool:
        """Install rows for unique non-resident ``ids``; returns False
        (installing nothing) when they cannot all fit without evicting a
        protected id — the caller falls back to a per-round dispatch.
        Eviction order: free slots first, then ascending F_λ."""
        ids = np.asarray(ids)
        m = len(ids)
        if m == 0:
            return True
        if self.slots == 0 or m > self.slots:
            return False
        with self._lock:
            free = self.slots - self._cursor
            spill = max(0, m - free)
            take = m - spill
            slots = np.empty((m,), np.int64)
            if spill:
                occ_ids = self.slot_hid
                if f_lam is not None:
                    key = np.asarray(f_lam, np.float64)[
                        np.clip(occ_ids, 0, None)].copy()
                else:
                    key = np.arange(len(occ_ids), dtype=np.float64)
                key[occ_ids < 0] = np.inf       # unpublished slots: not victims
                if protect is not None:
                    ps = self.h2s[np.asarray(protect)]
                    key[ps[ps >= 0]] = np.inf
                victims = np.argpartition(key, spill - 1)[:spill]
                if not np.isfinite(key[victims]).all():
                    return False                # would evict a protected row
                old = occ_ids[victims]
                self.h2s[old[old >= 0]] = -1
                slots[take:] = victims
                self.evictions += int(spill)
            if take:
                slots[:take] = np.arange(self._cursor, self._cursor + take)
                self._cursor += take
            self.rows[slots] = np.asarray(rows, np.int32)
            self.slot_hid[slots] = ids
            self.h2s[ids] = slots.astype(np.int32)
            self.installs += m
            self._dirty = True
            return True

    def lookup(self, ids):
        """(rows [m, R], resident [m]) host snapshot for unique ids — one
        locked read, so a concurrent install can never pair an id with
        another id's just-evicted slot contents."""
        ids = np.asarray(ids)
        with self._lock:
            s = self.h2s[ids]
            ok = s >= 0
            rows = np.full((len(ids), self.degree), -1, np.int32)
            rows[ok] = self.rows[s[ok]]
            return rows, ok

    def synced(self):
        """Publish (rows, h2s) device mirrors; both republished together
        so a dispatch can never pair an old directory with new rows."""
        with self._lock:
            if self._dirty or self._rows_j is None:
                self._rows_j = jnp.asarray(self.rows)
                self._h2s_j = jnp.asarray(self.h2s)
                self._dirty = False
            return self._rows_j, self._h2s_j


def warm_topo_cache(backend, slots: int) -> TopoCache:
    """Build, warm and attach the device-resident topology row cache for
    a tiered backend: full residency when ``slots`` covers the capacity,
    else the top-E_in live rows. The cache is a PURE cache of the store's
    adjacency truth — the engine calls this both at fresh build and after
    crash recovery (``wal.recover``), where every device mirror is
    rebuilt from the recovered host state."""
    cap = backend.capacity
    slots = slots or cap
    topo = TopoCache(cap, slots, backend.degree)
    topo.validate(backend.store)
    live = np.flatnonzero(backend.alive[:backend.n])
    if live.size > slots:          # partial cache: warm the hottest rows
        live = live[np.argsort(-backend.e_in[live], kind="stable")[:slots]]
    if live.size:
        topo.install(live, backend.store.peek_rows(live))
    backend.attach_topo(topo)
    return topo


def apply_wavp_host(hp: HostPlacement, acc_ids, acc_hit, sp: SearchParams,
                    *, alive, e_in, fetch_vectors, now=0,
                    cascade_promote: bool = True) -> None:
    """Post-batch placement (Algorithm 2) over host mirrors — the tiered
    twin of ``apply_wavp`` with the same decision rules.

    acc_ids/acc_hit: [B, rounds·beam·R] accessed ids (-1 pad) and
    device-hit flags from the frontier executor's round logs. alive/e_in:
    host graph metadata arrays. fetch_vectors(ids) resolves promoted
    payloads through the cascading host-window/disk lookup.

    ``cascade_promote``: batched serving touches every cached resident
    every batch, so the strict clock rule (ref==1 slots are untouchable
    this sweep) re-protects the whole cache each pass and promotion
    freezes at the cold-start set — cascade hits (ids served by host or
    disk during search) can then never earn a device slot no matter
    their F_λ. With the flag on (default), clock protection *orders* the
    sweep (ref==0 residents are still evicted first) but a protected
    resident is displaced when the incomer's F_λ strictly beats it —
    predictive replacement stays in charge, the freeze is gone.
    """
    N = hp.h2d.shape[0]
    M = hp.n_slots
    ids = np.asarray(acc_ids).reshape(-1)
    hit = np.asarray(acc_hit).reshape(-1)
    valid = ids >= 0

    # bincount, not np.add.at: the access log is ~rounds·beam·R·B ids per
    # batch and add.at's generalized fancy-index path costs ~10x a bincount
    counts = np.bincount(ids[valid], minlength=N).astype(np.float32)
    miss_counts = np.bincount(ids[valid & ~hit],
                              minlength=N).astype(np.float32)

    if sp.policy == "lru":
        f_recent = np.where(counts > 0, np.float32(now) + 1.0, hp.f_recent)
    else:
        decay = np.float32(1.0 if sp.policy == "lfu" else sp.decay)
        f_recent = hp.f_recent * decay + counts
    hp.f_recent = f_recent.astype(np.float32)

    n_acc = int(valid.sum())
    n_hit = int((valid & hit).sum())
    c = hp.counters
    c["accesses"] += n_acc
    c["hits"] += n_hit
    c["misses"] += n_acc - n_hit

    if sp.policy == "never":
        c["cpu_computed"] += n_acc - n_hit
        return

    if sp.policy in ("wavp", "always"):
        score = hp.scores(e_in)
    else:
        score = hp.f_recent

    thr = hp.theta if sp.policy == "wavp" else -np.inf
    cand_mask = (miss_counts > 0) & (hp.h2d < 0) & np.asarray(alive, bool) \
        & (score > thr)
    cand_ids = np.where(cand_mask)[0]
    P = min(sp.max_promote, M, cand_ids.size)
    n_prom = n_evict = 0
    # copy-on-write: concurrent search threads resolve hits through
    # hp.view, so mutations land on fresh copies published in one
    # ``view`` assignment (stale-by-one-batch reads fine, torn reads not)
    h2d, slot_hid = hp.h2d.copy(), hp.slot_hid.copy()
    vectors, slot_ver = hp.vectors, hp.slot_ver
    vslot = np.empty((0,), np.int64)
    if P > 0:
        top = cand_ids[np.argpartition(-score[cand_ids], P - 1)[:P]]
        top = top[np.argsort(-score[top])]
        prom_score = score[top]

        occ = hp.slot_hid >= 0
        occ_score = np.where(occ, score[np.clip(hp.slot_hid, 0, None)],
                             -np.inf)
        protected = (hp.ref > 0) & occ
        if cascade_promote:
            # empty first, then ref==0 ascending F_λ, then ref==1
            # ascending F_λ; any occupant yields to a strictly hotter
            # incomer (see docstring — protection orders, never freezes)
            victims = np.lexsort((occ_score, protected))[:P]
            improves = ~occ[victims] | (occ_score[victims] < prom_score)
        else:
            evict_key = np.where(~occ, -np.inf,
                                 np.where(protected, np.inf, occ_score))
            victims = np.argsort(evict_key, kind="stable")[:P]
            improves = ~protected[victims] & (
                (evict_key[victims] < prom_score) | ~occ[victims])

        vslot = victims[improves]
        new_hid = top[improves]
        old_hid = hp.slot_hid[vslot]
        evicted = old_hid[old_hid >= 0]
        vectors, slot_ver = hp.vectors.copy(), hp.slot_ver.copy()
        h2d[evicted] = -1
        payload = np.asarray(fetch_vectors(new_hid), vectors.dtype)
        vectors[vslot] = payload
        slot_hid[vslot] = new_hid.astype(np.int32)
        h2d[new_hid] = vslot.astype(np.int32)
        slot_ver[vslot] = 0
        n_prom = int(improves.sum())
        n_evict = int(evicted.size)

    # clock ref refresh EVERY batch, promotions or not (same as the jit
    # twin): hits this batch + fresh entries get a second chance
    ref = np.zeros((M,), np.int8)
    hit_ids = ids[valid & hit]
    hit_slots = h2d[hit_ids]
    ref[hit_slots[hit_slots >= 0]] = 1
    ref[vslot] = 1
    hp.vectors, hp.slot_hid, hp.h2d = vectors, slot_hid, h2d
    hp.slot_ver, hp.ref = slot_ver, ref
    hp.view = CacheView(h2d, vectors)

    if sp.policy == "wavp":
        mr = (n_acc - n_hit) / max(n_acc, 1)
        mean_f = (float(score[cand_mask].sum()) / max(int(cand_mask.sum()), 1))
        hp.theta = float(np.clip(hp.theta * 0.95 + 0.05 * mr * mean_f,
                                 1e-3, 1e6))

    c["promotions"] += n_prom
    c["evictions"] += n_evict
    c["transfers"] += n_prom
    c["cpu_computed"] += (n_acc - n_hit) - n_prom
