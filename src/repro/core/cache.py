"""Workload-Aware Vector Placement (paper §4.3, Algorithm 2) + baselines.

The prediction function F_λ(x) = α·F_recent(x,t) + β·log(1+E_in(x)) reduces
the gain test gain(x) > 0 to the threshold test F_λ(x) > θ with
θ = T_transfer/(T_CPU − T_GPU) (paper's theoretical analysis). Placement is
applied once per search batch with transfers amortized over the batch
(paper: 2048-vector transfer batches).

Eviction is the paper's clock-sweep with predicted-frequency tie-break,
*vectorized* for the TPU (DESIGN.md §2): empty slots are used first, then
slots with reference bit 0 in ascending F_λ; ref bits are refreshed by the
batch's cache hits (one sweep per batch). An exact sequential clock lives in
``clock_reference.py`` as the semantics oracle for tests.

Baseline policies (paper §6.3): LRU, LFU, LRFU, ``never`` (w/o WAVP — always
compute misses on the capacity tier), ``always`` (promote every miss).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.types import CacheState, GraphState, IndexState, SearchParams, Stats


def f_lambda(cache: CacheState, graph: GraphState):
    """F_λ(x) = α·F_recent + β·log(1+E_in) (paper eq. 2)."""
    return (cache.alpha * cache.f_recent
            + cache.beta * jnp.log1p(graph.e_in.astype(jnp.float32)))


def _policy_scores(policy, cache, graph):
    """Higher score = more worth caching. f_recent holds the policy's own
    statistic: timestamps for LRU, raw counts for LFU, decayed counts (CRF)
    for LRFU/WAVP."""
    if policy in ("wavp", "always"):
        return f_lambda(cache, graph)
    return cache.f_recent


@partial(jax.jit, static_argnames=("sp",))
def apply_wavp(state: IndexState, acc_ids, acc_hit, sp: SearchParams,
               now=0) -> IndexState:
    """Post-batch placement pass (Algorithm 2, batched).

    acc_ids [B, I*R] accessed ids (-1 pad), acc_hit [B, I*R] hit flags.
    """
    graph, cache, stats = state
    N = graph.capacity
    M = cache.n_slots

    ids = acc_ids.reshape(-1)
    hit = acc_hit.reshape(-1)
    valid = ids >= 0
    cid = jnp.clip(ids, 0)

    counts = jnp.zeros((N,), jnp.float32).at[cid].add(valid.astype(jnp.float32))
    miss_counts = jnp.zeros((N,), jnp.float32).at[cid].add(
        (valid & ~hit).astype(jnp.float32))

    if sp.policy == "lru":
        f_recent = jnp.where(counts > 0, jnp.float32(now) + 1.0,
                             cache.f_recent)
    else:
        decay = jnp.float32(1.0 if sp.policy == "lfu" else sp.decay)
        f_recent = cache.f_recent * decay + counts
    cache = cache._replace(f_recent=f_recent)

    n_acc = valid.sum()
    n_hit = (valid & hit).sum()
    stats = stats._replace(
        accesses=stats.accesses + n_acc.astype(jnp.int32),
        hits=stats.hits + n_hit.astype(jnp.int32),
        misses=stats.misses + (n_acc - n_hit).astype(jnp.int32),
    )

    if sp.policy == "never":
        # w/o WAVP: all misses computed in place on the capacity tier
        stats = stats._replace(cpu_computed=stats.cpu_computed
                               + (n_acc - n_hit).astype(jnp.int32))
        return IndexState(graph, cache, stats)

    score = _policy_scores(sp.policy, cache, graph)

    # ---- selective prefetch (Alg. 2 lines 1-2): F_λ(x) > θ to promote ----
    thr = cache.theta if sp.policy == "wavp" else jnp.float32(-jnp.inf)
    cand_mask = (miss_counts > 0) & (cache.h2d < 0) & graph.alive \
        & (score > thr)
    cand_score = jnp.where(cand_mask, score, -jnp.inf)
    P = min(sp.max_promote, M)
    prom_score, prom_ids = jax.lax.top_k(cand_score, P)
    prom_valid = jnp.isfinite(prom_score)

    # ---- predictive replacement (Alg. 2 lines 3-11), vectorized clock ----
    occ_score = jnp.where(cache.slot_hid >= 0,
                          score[jnp.clip(cache.slot_hid, 0)], -jnp.inf)
    # eviction priority: empty slots first, then ref==0 by ascending F_λ;
    # ref==1 slots are protected this sweep (second chance).
    empty = cache.slot_hid < 0
    protected = (cache.ref > 0) & ~empty
    evict_key = jnp.where(empty, -jnp.inf,
                          jnp.where(protected, jnp.inf, occ_score))
    victim_order = jnp.argsort(evict_key)
    victims = victim_order[:P]
    victim_ok = ~protected[victims]
    # only evict a victim whose score is lower than the incomer's
    improves = prom_valid & victim_ok & (
        (evict_key[victims] < prom_score) | empty[victims])

    vslot = jnp.where(improves, victims, M)        # M = scatter no-op row
    old_hid = jnp.where(improves, cache.slot_hid[jnp.clip(victims, 0)], -1)
    new_hid = jnp.where(improves, prom_ids, -1)

    h2d = cache.h2d.at[jnp.clip(old_hid, 0)].set(
        jnp.where(old_hid >= 0, -1, cache.h2d[jnp.clip(old_hid, 0)]))
    h2d = h2d.at[jnp.clip(new_hid, 0)].set(
        jnp.where(new_hid >= 0, vslot.astype(jnp.int32),
                  h2d[jnp.clip(new_hid, 0)]))

    slot_hid = jnp.concatenate([cache.slot_hid, jnp.full((1,), -1, jnp.int32)])
    slot_hid = slot_hid.at[vslot].set(jnp.where(improves, new_hid, -1))[:M]
    vec_pad = jnp.concatenate([cache.vectors,
                               jnp.zeros((1, cache.vectors.shape[1]))], 0)
    vec_pad = vec_pad.at[vslot].set(graph.vectors[jnp.clip(new_hid, 0)])
    vectors = vec_pad[:M]
    ver_pad = jnp.concatenate([cache.slot_ver, jnp.zeros((1,), jnp.int32)])
    ver_pad = ver_pad.at[vslot].set(graph.version[jnp.clip(new_hid, 0)])

    # clock ref refresh: slots hit this batch get a second chance
    hit_slot = jnp.where(valid & hit, cache.h2d[cid], -1)
    ref = jnp.zeros((M + 1,), jnp.int8).at[jnp.clip(hit_slot, 0)].set(
        jnp.int8(1))
    ref = ref.at[vslot].set(jnp.int8(1))[:M]       # fresh entries referenced

    n_prom = improves.sum().astype(jnp.int32)
    n_evict = (improves & (old_hid >= 0)).sum().astype(jnp.int32)
    cache = cache._replace(vectors=vectors, slot_hid=slot_hid, h2d=h2d,
                           ref=ref, slot_ver=ver_pad[:M])

    # ---- θ adaptation (paper §4.4): more selective when misses rise with
    # high predicted demand ----
    if sp.policy == "wavp":
        miss_rate = (n_acc - n_hit) / jnp.maximum(n_acc, 1)
        mean_f = jnp.where(cand_mask, score, 0.0).sum() / jnp.maximum(
            cand_mask.sum(), 1)
        pressure = miss_rate * mean_f
        theta = jnp.clip(cache.theta * 0.95 + 0.05 * pressure, 1e-3, 1e6)
        cache = cache._replace(theta=theta)

    stats = stats._replace(
        promotions=stats.promotions + n_prom,
        evictions=stats.evictions + n_evict,
        transfers=stats.transfers + n_prom,
        cpu_computed=stats.cpu_computed
        + (n_acc - n_hit).astype(jnp.int32) - n_prom)
    return IndexState(graph, cache, stats)


def miss_rate(stats: Stats) -> float:
    a = max(int(stats.accesses), 1)
    return float(stats.misses) / a
