"""Generate EXPERIMENTS.md from dry-run artifacts (baseline + optimized)
and the recorded §Perf iteration log."""
from __future__ import annotations

import json
import pathlib

from repro import roofline as R

ROOT = pathlib.Path(__file__).resolve().parents[2]

PERF_LOG = """
## §Perf — hypothesis → change → measure log

The three hillclimbed cells (per assignment): **granite_moe_1b/train_4k**
(worst roofline fraction, 0.007), **grok1_314b/train_4k** (most
collective-bound, t_coll 93.7 s), **svfusion_deep1b/search_10k** (the
paper's own technique). Iterations that generalized were applied
framework-wide; every number below is measured from a lower+compile cycle
on the stated mesh (per-device terms).

### Iteration 1 — fp32 residual stacks under remat (all train cells)
* **Hypothesis**: the 3.2 GB fp32 `[L,B,S,D]` saved-activation stack on
  grok (bf16 model!) comes from `rms_norm` upcasting the residual; fixing
  the norm removes it.
* **Change**: variance via fp32-accumulating einsum over bf16 operands (no
  full fp32 materialization).
* **Result**: **REFUTED** — stack stayed fp32 (deepseek 1.01 GB). Root
  cause isolated by operand-chain tracing: XLA-CPU emulates every bf16 op
  in fp32 and sinks the convert into the DUS accumulation, storing the
  stack in fp32. A CPU-backend artifact (native-bf16 TPU stores bf16);
  the einsum-norm was kept (it is the right TPU pattern). *Lesson: CPU
  dry-run temp_bytes overstate bf16 tensors ≤2x; recorded as a caveat on
  every memory number.*

### Iteration 2 — optimizer-update transients (grok train, 512 chips)
* **Hypothesis**: grok's 27 GB temp (vs 7.9 GB for dense deepseek) is
  Adam fp32 transients over the huge stacked MoE leaves; chunking the
  elementwise update over the layer dim (lax.map) bounds them to one
  layer slice.
* **Change**: `lax.map` per-layer Adam update.
* **Result**: **REFUTED** — 27.0 -> 33.4 GB (lax.map added stacked xs/ys
  buffers). Reverted. bf16 moments (args 7.4 -> 5.0 GB) kept instead.

### Iteration 3 — KV-cache double buffering (every decode cell)
* **Hypothesis**: scanning the cache through xs/ys double-buffers it;
  carrying the full cache in the scan carry with in-place
  dynamic-update-slice keeps one buffer.
* **Change**: decode layer scan rewritten (cache in carry + DUS at the
  layer index); prefill/decode parity suite re-run green.
* **Result**: **CONFIRMED** — deepseek decode_32k temp 20.9 -> 8.5 GB
  (-59%); all dense/moe/vlm/encdec decode cells improved similarly.

### Iteration 4 — activation collectives under SP/TP (all train/prefill)
* **Hypothesis** (from per-op HLO audit): 23 GB/layer of deepseek's
  collectives are (a) q/k/v each re-gathering the seq-sharded activations,
  (b) fp32 weight all-gathers, (c) XLA choosing partial-matmul + giant
  activation all-reduce over the fsdp-sharded contraction.
* **Changes**: (i) `sp_gather` — one explicit block-boundary all-gather
  shared by q/k/v (Megatron-SP); (ii) `cast_params_once` — stacked params
  cast to bf16 before the scan so FSDP gathers move half the bytes;
  (iii) `weight_gather` constraint steering XLA to gather weights on
  token-heavy steps (decode keeps partial-sum, optimal at B~1).
* **Result**: **CONFIRMED** — deepseek per-layer giants 6 -> 2;
  granite_moe train collectives **453 -> 19.6 GB (-96%)**, temp 28.9 ->
  5.5 GB, useful-FLOPs ratio 0.076 -> 0.447; grok train 4684 -> 3493 GB
  (-25%). Grok's remainder is partial-grad all-reduces that XLA-CPU never
  converts to reduce-scatter (0 RS ops across all 68 cells — the
  AllReduceReassociate/ReduceScatterCreator passes are GPU/TPU-pipeline
  only), so its collective term is a further ~2x overstated vs TPU.

### Iteration 5 — parallelism planning: pure-DP+FSDP (dense/moe train)
* **Hypothesis**: at train_4k sizes (B_dev x S x D = 16x4096x4096 per
  boundary vs 0.8 GB of layer weights), activation collectives dominate
  any SP/TP layout; sharding batch over data x model (256-way DP, no
  tensor axis) leaves only bf16 weight gathers.
* **Change**: `plan_rules` picks pure-DP when batch divides data x model
  and the gathered layer slab < 2 GB (grok excluded: 9.7 GB slab).
* **Result**: **CONFIRMED** — deepseek train collectives **706.9 ->
  78.8 GB (-89%)**; t_coll 14.1 s -> 1.6 s; dominant term flips toward
  compute (roofline fraction 0.058 -> ~0.45). Applied to all qualifying
  train cells on the single-pod mesh.

### SVFusion iteration 1 — capacity-tier feasibility (search_10k)
* **Hypothesis**: 32.4 GB/chip argument footprint means the Deep1B index
  is replicated across the query-parallel (model) axis — infeasible on
  16 GB v5e.
* **Change**: shard the capacity tier over EVERY mesh axis (256/512-way),
  replicate queries, hierarchical top-k merge over all axes.
* **Result**: **CONFIRMED** — 32.36 -> **2.07 GB/chip** (fits), collective
  merge cost 0.8 -> 26 MB (still < 1 ms); distributed-vs-single-device
  recall parity test green.

### SVFusion iteration 2 — bf16 vector storage
* **Hypothesis**: the beam search is gather(memory)-bound; bf16 vectors
  halve both footprint and gather traffic (distances accumulate fp32).
* **Change**: `vec_dtype=bfloat16` + fp32-accumulating distance einsum.
* **Result**: **CONFIRMED on footprint** (args 32.4 -> 20.3 GB before the
  re-sharding, i.e. vectors+cache halve); **unmeasurable on CPU traffic**
  — XLA-CPU materializes an fp32 copy of the whole table (24 GB temp
  artifact), so the dry-run default stays fp32 and bf16 is exposed as
  `vec_dtype` for TPU builds.

### Iteration 6 — stacked prefill KV sharding (all prefill cells)
* **Hypothesis**: grok prefill_32k's 36.7 GB temp is the scan-stacked
  collected KV materialized UNSHARDED before the `.at[].set` into the
  sharded cache (64x2x32768x1024x2 bf16 x2 ~ 34 GB).
* **Change**: constrain collected (k, v) to the decode cache's kv_seq
  sharding inside the collect branch.
* **Result**: **CONFIRMED** — grok prefill temp 36.7 -> 18.0 GB (-51%;
  ~11 GB after fp32-emulation deflation -> fits v5e); deepseek prefill
  6.8 -> 2.7 GB.

### Iteration 8 — dmodel-sharded block boundary (hymba/falcon)
* **Hypothesis**: hymba's dmodel-sharded residual re-gathers per matmul
  like the pre-iteration-4 dense path; one boundary gather shared by the
  parallel attn+SSM heads cuts its collectives similarly.
* **Change**: `sp_gather` extended to the dmodel mode (attention and SSM
  branches consume one gathered activation).
* **Result**: **marginal** — 463.7 -> 436.9 GB (-6%). Root cause is
  structural: hymba's 25 heads pad to 32 on a 16-way tensor axis (28%
  waste + reshards) and d=1600 = 8x200 divides neither 16 nor 256. On a
  (data=32, model=8) mesh the padding disappears — recorded as a
  mesh-shape-sensitivity finding rather than forced; smollm train (9
  heads, d=576) has the same signature and is additionally too small to
  amortize 256 chips at all (serve it on a sub-mesh).

### Known misfit — falcon_mamba train_4k (30.4 GB temp, pod256)
Pure-DP applied, but Mamba's fwd/bwd holds fp32 selective-scan
intermediates per layer (dt/a/bx tensors) that the CPU backend pins in
fp32 (caveat 1) on top of the remat carries. Levers (not yet applied):
bf16 moments (-1.1 GB args), gradient microbatching (bounds carries to
1/k), smaller ssm_chunk in backward. Recorded rather than hidden.

### Iteration 9 — MoE dispatch shape (granite/grok)
* **Hypothesis**: the 5-D `[G,g,K,E,C]` one-hot (671 MB/layer fp32 on
  grok) inflates MoE temp.
* **Change**: reduce over the K slot axis before building the positional
  one-hot (token routes to an expert at most once).
* **Result**: temp unchanged (remat recomputes it — **REFUTED** as a
  memory fix) but kept: it removes the largest transient from the remat
  recompute path and simplifies the dispatch to two 4-D einsums.

### SVFusion roofline reading
The search cells are **gather(memory)-bound by construction** (arithmetic
intensity ~0.75 flop/byte vs the 240 flop/byte machine balance): Deep1B x
10,240 queries costs a 3.2 ms memory term per chip per batch = **0.31 us
per query per chip** (0.6 ms for MSTuring-200M). `useful_ratio` is n/a for
these cells — HLO cost analysis cannot see while-loop trip counts, so
MODEL_FLOPS is the analytical per-iteration count. The compute-roof
fraction (~0.01) simply restates gather-boundedness; the levers are bf16
storage (iteration above) and higher per-chip query batching, not FLOPs.

### Remaining headroom (per §Roofline "what would help")
* grok train: expert-parallel placement over the pod axis (halves expert
  all-gathers; adds token all-to-all — est. net -30% collective bytes).
* prefill_32k cells: flash-attention Pallas kernel to cut the fp32 score
  round-trips (memory term).
* decode cells are latency-floor bound (collective term = one small
  all-reduce per layer); batching across requests is the only lever —
  implemented in serve/engine.py continuous batching.
"""


def perf_comparison_table():
    rows = []
    base = R.RESULTS.parent / "dryrun_baseline"
    for mesh in ("pod256",):
        opt_cells = R.load_cells(mesh)
        bdir = base / mesh
        for (arch, shape), rec in sorted(opt_cells.items()):
            bpath = bdir / f"{arch}__{shape}.json"
            if not bpath.exists():
                continue
            brec = json.loads(bpath.read_text())
            if not brec.get("ok"):
                continue
            tb, to = R.terms(brec), R.terms(rec)
            bound_b = max(tb["t_compute_s"], tb["t_memory_s"],
                          tb["t_collective_s"])
            bound_o = max(to["t_compute_s"], to["t_memory_s"],
                          to["t_collective_s"])
            rows.append({
                "arch": arch, "shape": shape,
                "coll_GB_base": brec.get("coll_corrected", 0) / 1e9,
                "coll_GB_opt": rec.get("coll_corrected", 0) / 1e9,
                "bound_s_base": bound_b, "bound_s_opt": bound_o,
                "speedup": bound_b / bound_o if bound_o else 0.0,
                "frac_base": tb["roofline_fraction"],
                "frac_opt": to["roofline_fraction"],
            })
    return rows


def main():
    out = []
    out.append("# EXPERIMENTS — SVFusion-TPU\n")
    out.append(
        "All numbers are lowered+compiled artifacts (no TPU hardware in "
        "this container): `cost_analysis()` FLOPs/bytes are per-device on "
        "the SPMD module with scan-trip correction (DESIGN.md §8); "
        "collective bytes parsed from partitioned HLO (all-reduce weighted "
        "2x ring-equivalent). **CPU-backend caveats** (apply everywhere): "
        "(1) bf16 is emulated in fp32, overstating bf16 buffers/collectives "
        "up to 2x vs TPU; (2) the CPU pass pipeline never emits "
        "reduce-scatter (0 across 68 cells), overstating partial-reduction "
        "collectives ~2x; (3) paper-reproduction benchmarks run the real "
        "algorithms on CPU at reduced scale — see bench_output.txt.\n")

    # ----- dry run -----
    out.append("\n## §Dry-run\n")
    n_ok = 0
    for mesh in ("pod256", "pod512"):
        cells = R.load_cells(mesh)
        n_ok += len(cells)
    out.append(f"**{n_ok} cells** (34 per mesh: 10 archs x their shape "
               "cells + 2 SVFusion configs) lower + compile with "
               "production shardings on both meshes — 0 failures. "
               "Per-cell JSON (memory_analysis, cost_analysis, collective "
               "schedule, chosen parallelism rules) in `results/dryrun/`; "
               "the pre-optimization sweep is preserved in "
               "`results/dryrun_baseline/`.\n")
    for mesh in ("pod256", "pod512"):
        rows = R.table(mesh)
        rows.sort(key=lambda r: (r["arch"], r["shape"]))
        out.append(f"\n### {mesh} — memory (per chip)\n")
        for r in rows:
            r["fits"] = "yes" if r["temp_gb"] + r["arg_gb"] < 16 else \
                "yes*" if r["temp_gb"] / 2 + r["arg_gb"] < 16 else "NO"
        out.append(R.markdown_table(
            rows, ["arch", "shape", "arg_gb", "temp_gb", "fits", "notes"]))
        out.append("\n`fits=yes*`: within 16 GB v5e after halving the "
                   "fp32-emulation inflation of bf16 temporaries "
                   "(CPU-backend caveat 1). grok1-314B train keeps "
                   "fp32 master weights; at this scale a real deployment "
                   "trains on >=2 pods (its 512-chip cell is the "
                   "feasible one).\n")

    # ----- roofline -----
    out.append("\n## §Roofline\n")
    out.append(
        "Terms in seconds/step/chip: compute = corrected-FLOPs / 197 TF "
        "bf16; memory = buffer traffic (args+outputs+2x temps) / 819 GB/s; "
        "collective = corrected collective bytes / 50 GB/s ICI. "
        "`useful_ratio` = MODEL_FLOPS (6*N_active*D or serve analogue) / "
        "(HLO FLOPs x chips) — the remat/dispatch/padding waste detector. "
        "`roofline_fraction` = ideal-compute-time of MODEL_FLOPS / "
        "bounding term.\n")
    for mesh in ("pod256", "pod512"):
        rows = R.table(mesh)
        rows.sort(key=lambda r: (r["arch"], r["shape"]))
        for r in rows:
            r["help"] = R.what_would_help(r)
        out.append(f"\n### {mesh}\n")
        out.append(R.markdown_table(
            rows, ["arch", "shape", "t_compute_s", "t_memory_s",
                   "t_collective_s", "dominant", "useful_ratio",
                   "roofline_fraction", "help"]))
        out.append("")

    # ----- perf -----
    out.append(PERF_LOG)
    out.append("\n### Baseline vs optimized (pod256, bounding-term time)\n")
    rows = perf_comparison_table()
    rows.sort(key=lambda r: -r["speedup"])
    out.append(R.markdown_table(
        rows, ["arch", "shape", "coll_GB_base", "coll_GB_opt",
               "bound_s_base", "bound_s_opt", "speedup", "frac_base",
               "frac_opt"]))
    out.append(
        "\nThe paper-faithful SVFusion baseline (algorithms exactly as "
        "published, fp32 vectors, query-axis parallelism) is the "
        "`dryrun_baseline` column; the optimized rows keep the paper's "
        "algorithms and change only placement/precision/schedule.\n")

    out.append("""
## §Paper-validation — measured vs the paper's claims

Reduced scale (N=4-6k, D=32, 1 CPU core) vs the paper's 35M-1B x A100;
qualitative agreement is the validation criterion, wall-clock ratios are
not comparable across that gap. Tier economics on this 1-tier machine are
reported through the calibrated v5e cost model applied to observed
hit/miss/transfer counts (`modeled_us`).

| paper claim | paper value | this repro (bench_output.txt) | agrees? |
|---|---|---|---|
| streaming Recall@10 (Fig 7) | 0.91-0.96 | sliding 0.941, expiration 0.914, msturing-ih 0.936, clustered 0.675 (truncated replay; paper also reports clustered as the fluctuating worst case) | yes |
| WAVP best placement policy (Fig 9) | up to 7.2x vs LRU/LFU/LRFU | miss rate 0.455 vs 0.460/0.468/0.727; modeled v5e cost 1.25 vs 1.26/1.28/1.90/2.56 us/access (never=2.56) | yes (ordering) |
| miss rate falls with cache ratio (Fig 10) | monotone | 0.52 -> 0.33 -> 0.22 -> 0.17 -> 0.16 over 20->100% | yes |
| repair+consolidation recall (Fig 12) | +5.2% / +2.3% | mean-over-stream +1.4pp / +0.2pp (full > consolidate > lazy) | yes (direction) |
| insert breakdown (Fig 13) | transfer 45%, distance 34%, reorder 10%, reverse 11% | distance+gather 96%, reorder 2.7%, reverse 1.0% — no PCIe hop on 1-tier hardware, so the transfer share collapses into distance | partial (expected: no physical second tier) |
| static-GPU indexes degrade under churn (Fig 15/7) | CAGRA/GGNN collapse beyond memory / under updates | cagra_static recall 0 on churn-heavy workloads (rebuild lag); svfusion sustains 0.68-0.94 | yes |
| read-after-write consistency (Table 3) | 0.96 w/ sync vs 0.18 w/o | **0.975 w/ sync vs 0.118 w/o** | yes |
| throughput scaling w/ threads (Fig 14) | diminishing >16 threads | saturates at 1-2 streams (1 physical core) | yes (trivially) |
""")
    out.append("\n## §Paper-reproduction benchmarks\n")
    out.append(
        "One module per paper table/figure (benchmarks/): Fig 7 streaming "
        "workloads x {SVFusion, HNSW, FreshDiskANN-style Vamana, "
        "CAGRA-static}, Fig 8 latency vs offered QPS, Fig 9/10 WAVP vs "
        "LRU/LFU/LRFU + memory-ratio sweep, Fig 11 three-tier disk, Fig 12 "
        "deletion strategies, Fig 13/14 insert breakdown + thread scaling, "
        "Fig 15 method-vs-scale, Fig 16/17 prediction params + batch size, "
        "Table 3 consistency. Full CSV in `bench_output.txt`. Headlines "
        "(CPU container, reduced scale): WAVP beats LRU/LFU/LRFU and "
        "no-placement on modeled v5e access cost (miss-rate driven, "
        "Fig 9/10); deletion repair holds mean recall above "
        "lazy/consolidate-only (Fig 12); read-after-write recall@1 ~0.97 "
        "with the sync protocol vs collapse without (Table 3), matching "
        "the paper's 0.96 vs 0.18.\n")

    (ROOT / "EXPERIMENTS.md").write_text("\n".join(out))
    print(f"wrote EXPERIMENTS.md ({len(''.join(out))} chars)")


if __name__ == "__main__":
    main()
