"""Shared utilities: pytree helpers, sharding helpers, timing, rng."""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Any, Callable, Iterable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


def tree_size(tree) -> int:
    """Total number of elements across all leaves."""
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def tree_bytes(tree) -> int:
    return sum(int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
               for x in jax.tree.leaves(tree))


def cast_tree(tree, dtype):
    return jax.tree.map(lambda x: x.astype(dtype), tree)


def pad_to_multiple(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def shape_struct(shape, dtype=jnp.bfloat16):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


class Timer:
    """Wall-clock timer accumulating named spans (host-side benchmarking)."""

    def __init__(self):
        self.spans: dict[str, float] = {}
        self.counts: dict[str, int] = {}

    @contextlib.contextmanager
    def span(self, name: str):
        t0 = time.perf_counter()
        try:
            yield
        finally:
            dt = time.perf_counter() - t0
            self.spans[name] = self.spans.get(name, 0.0) + dt
            self.counts[name] = self.counts.get(name, 0) + 1

    def summary(self) -> dict[str, float]:
        return dict(self.spans)


def block_tree(tree):
    """Block until all leaves are ready (for timing)."""
    for leaf in jax.tree.leaves(tree):
        if hasattr(leaf, "block_until_ready"):
            leaf.block_until_ready()
    return tree


def percentile(xs: Iterable[float], p: float) -> float:
    xs = sorted(xs)
    if not xs:
        return float("nan")
    idx = min(len(xs) - 1, int(round(p / 100.0 * (len(xs) - 1))))
    return xs[idx]


def spec(*names) -> P:
    """Shorthand PartitionSpec constructor."""
    return P(*names)


def current_mesh_axis_sizes() -> dict[str, int]:
    from repro import compat
    mesh = compat.get_abstract_mesh()
    if mesh is None or mesh.empty:
        return {}
    return dict(zip(mesh.axis_names, mesh.axis_sizes))
