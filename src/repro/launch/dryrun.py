"""Multi-pod dry-run: lower + compile every (architecture × shape × mesh)
cell with production shardings, record memory/cost/collective analysis.

MUST set the placeholder device count before ANY other import (jax locks
the device count on first init).
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse          # noqa: E402
import contextlib        # noqa: E402
import json              # noqa: E402
import pathlib           # noqa: E402
import re                # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402

from repro import compat                                       # noqa: E402
from repro.configs.base import ARCH_IDS, SHAPES, shape_cells   # noqa: E402
from repro.launch.mesh import make_production_mesh             # noqa: E402
from repro.models.sharding import axis_size, rules_override    # noqa: E402

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"

_DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
                "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8}

_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_LINE_RE = re.compile(
    r"=\s+(.+?)\s+(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo: str) -> dict:
    """Per-device bytes moved by collectives, parsed from partitioned HLO.

    Weights (ring algorithms): all-reduce 2x output size; others 1x.
    ``-done`` ops are skipped (their ``-start`` was already counted).
    """
    out = {op: 0 for op in _COLL_OPS}
    counts = {op: 0 for op in _COLL_OPS}
    for line in hlo.splitlines():
        if "-done(" in line:
            continue
        m = _LINE_RE.search(line)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        b = _shape_bytes(shape_str)
        out[op] += b * (2 if op == "all-reduce" else 1)
        counts[op] += 1
    return {"bytes": out, "counts": counts,
            "total_bytes": sum(out.values())}


def _lower_compile(fn, args, in_sh, out_sh, donate):
    kw = {}
    if in_sh is not None:
        kw["in_shardings"] = compat.resolve_shardings(in_sh)
    if out_sh is not None:
        kw["out_shardings"] = compat.resolve_shardings(out_sh)
    if donate:
        kw["donate_argnums"] = donate
    jitted = jax.jit(fn, **kw)
    lowered = jitted.lower(*args)
    compiled = lowered.compile()
    return lowered, compiled


def analyze(compiled) -> dict:
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):   # pre-0.5 returns [dict]
        ca = ca[0] if ca else {}
    ma = compiled.memory_analysis()
    hlo = compiled.as_text()
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        "collectives": collective_bytes(hlo),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "generated_code_bytes": ma.generated_code_size_in_bytes,
        },
    }


def run_cell(arch: str, shape: str, multi_pod: bool, force=False) -> dict:
    mesh_name = "pod512" if multi_pod else "pod256"
    out_path = RESULTS / mesh_name / f"{arch}__{shape}.json"
    out_path.parent.mkdir(parents=True, exist_ok=True)
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    from repro.launch import steps as Steps
    Steps.run_plan_rules = Steps.plan_rules
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    record = {"arch": arch, "shape": shape, "mesh": mesh_name,
              "n_chips": int(mesh.devices.size), "ok": False}
    try:
        with compat.use_mesh(mesh):
            rules = Steps.run_plan_rules(arch, shape)
            record["rules"] = {k: list(v) for k, v in rules.items()}
            with rules_override(**rules):
                if arch.startswith("svfusion"):
                    bundle = Steps.build_svfusion_bundle(shape, mesh)
                else:
                    bundle = Steps.build_bundle(arch, shape)
                lowered, compiled = _lower_compile(
                    bundle.fn, bundle.abstract_args, bundle.in_shardings,
                    bundle.out_shardings, bundle.donate_argnums)
                record.update(analyze(compiled))
                record["model_flops"] = bundle.model_flops
                record["notes"] = bundle.notes
                record["kind"] = bundle.kind
                units = []
                for u in bundle.cost_units:
                    _, uc = _lower_compile(u.fn, u.abstract_args,
                                           u.in_shardings, None, ())
                    ua = analyze(uc)
                    ua["name"], ua["multiplier"] = u.name, u.multiplier
                    units.append(ua)
                record["units"] = units
                # scan-corrected totals (DESIGN.md §8)
                record["flops_corrected"] = record["flops"] + sum(
                    u["flops"] * u["multiplier"] for u in units)
                record["bytes_corrected"] = record["bytes_accessed"] + sum(
                    u["bytes_accessed"] * u["multiplier"] for u in units)
                record["coll_corrected"] = (
                    record["collectives"]["total_bytes"] + sum(
                        u["collectives"]["total_bytes"] * u["multiplier"]
                        for u in units))
        record["ok"] = True
    except Exception as e:
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]
    record["elapsed_s"] = round(time.time() - t0, 2)
    out_path.write_text(json.dumps(record, indent=1))
    status = "OK " if record["ok"] else "FAIL"
    print(f"[{status}] {mesh_name} {arch:20s} {shape:12s} "
          f"{record['elapsed_s']:7.1f}s "
          f"{record.get('error', '')[:90]}", flush=True)
    return record


def all_cells():
    cells = []
    for arch in ARCH_IDS:
        for shape in shape_cells(arch):
            cells.append((arch, shape))
    cells.append(("svfusion_deep1b", "search_10k"))
    cells.append(("svfusion_msturing", "search_1k"))
    return cells


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["pod256", "pod512", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    meshes = {"pod256": [False], "pod512": [True],
              "both": [False, True]}[args.mesh]
    if args.all:
        cells = all_cells()
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    n_ok = n_fail = 0
    for multi in meshes:
        for arch, shape in cells:
            rec = run_cell(arch, shape, multi, force=args.force)
            n_ok += rec["ok"]
            n_fail += not rec["ok"]
    print(f"dry-run: {n_ok} ok, {n_fail} failed")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
