"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches jax device state. The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import (see dryrun.py); tests and benches see the real single device.
"""
from __future__ import annotations

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_test_mesh(shape=(2, 4), axes=("data", "model")):
    """Small mesh for in-process distributed tests (8 fake devices)."""
    return compat.make_mesh(shape, axes)
