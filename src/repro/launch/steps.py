"""Step builders for the dry-run / roofline: per (arch × shape) jit-able
train/prefill/decode step functions with abstract inputs, shardings, and
cost units.

Cost units (DESIGN.md §8): ``cost_analysis()`` counts a ``lax.scan`` body
once, so each bundle carries per-layer body functions + trip multipliers.
Units are lowered with a *cost-variant* config (attn_chunk=0, single SSM
chunk) whose FLOPs equal the chunked production variant, avoiding nested
scan corrections.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import (ARCH_IDS, SHAPES, ModelConfig, ShapeConfig,
                                load_config, shape_cells)
from repro.models import layers as Lyr
from repro.models import mamba as M
from repro.models import model as Mdl
from repro.models.sharding import ax, axis_size
from repro.train import optimizer as Opt

SDS = jax.ShapeDtypeStruct
I32, F32, BF16 = jnp.int32, jnp.float32, jnp.bfloat16


@dataclass
class CostUnit:
    name: str
    multiplier: int
    fn: Callable
    abstract_args: tuple
    in_shardings: Any


@dataclass
class StepBundle:
    arch: str
    shape: str
    kind: str
    fn: Callable
    abstract_args: tuple
    in_shardings: Any
    out_shardings: Any
    donate_argnums: tuple
    cost_units: list
    model_flops: float
    notes: str = ""


# ---------------------------------------------------------------------------
# Per-cell policy: memory levers chosen so each cell fits 16 GB/chip v5e
# ---------------------------------------------------------------------------

def plan_rules(arch: str, shape_name: str) -> dict:
    """Pick the parallelism scheme per cell (call under the mesh context).

    Pure-DP+FSDP (batch over data×model, NO tensor axis) beats SP/TP for
    token-heavy steps whenever the batch divides the mesh and the per-layer
    gathered weight slab stays small: zero per-layer activation collectives,
    only bf16 weight all-gathers (§Perf iteration 4). Falls back to the
    SP/TP scheme (DEFAULT_RULES) otherwise — e.g. grok (9.7 GB expert slab)
    and prefill_32k (batch 32 < data×model).
    """
    from repro.models.sharding import axis_size
    shape = SHAPES[shape_name] if shape_name in SHAPES else None
    if shape is None or arch.startswith("svfusion"):
        return {}
    rules: dict = {}
    if shape.global_batch % max(axis_size("batch"), 1) != 0:
        rules["batch"] = ()          # e.g. long_500k batch=1
        return rules
    cfg = load_config(arch)
    from repro import compat
    mesh = compat.get_abstract_mesh()
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    data_model = sizes.get("data", 1) * sizes.get("model", 1)
    layer_slab_gb = count_params(cfg) / max(cfg.n_layers, 1) * 2 / 1e9
    dims_ok = (cfg.d_model % data_model == 0
               and (cfg.d_ff == 0 or cfg.d_ff % data_model == 0))
    if (shape.kind == "train" and shape.global_batch % data_model == 0
            and layer_slab_gb < 2.0 and "pod" not in sizes and dims_ok):
        rules["batch"] = ("data", "model")
        rules["fsdp"] = ("data", "model")
        rules["tensor"] = ()
    return rules


def tune_config(cfg: ModelConfig, shape: ShapeConfig) -> ModelConfig:
    kw: dict = {}
    if shape.kind in ("train", "prefill"):
        kw["gather_weights"] = True   # token-heavy: gather weights, don't
        # partial-sum over the fsdp-sharded contraction (§Perf)
        if shape.seq_len >= 8192 or (shape.kind == "train"
                                     and cfg.d_model >= 4096):
            kw["attn_chunk"] = 2048
        # residual-stream sharding when per-device layer carries get big
        est = (shape.global_batch / 32) * shape.seq_len * cfg.d_model * 2 \
            * max(cfg.n_layers, 1)
        if shape.kind == "train" and est > 3e9:
            kw["residual_shard"] = "dmodel" if cfg.family in ("ssm", "hybrid") \
                else "seq"
        if shape.kind == "prefill":
            kw["remat_policy"] = "none"       # inference: no backward
            if cfg.family in ("ssm", "hybrid"):
                kw["residual_shard"] = "dmodel"
            elif shape.seq_len * cfg.d_model * 2 > 5e7:
                kw["residual_shard"] = "seq"
    if shape.kind == "decode":
        kw["remat_policy"] = "none"
        kw["moe_group"] = 1
    return cfg.replace(**kw) if kw else cfg


def cost_variant(cfg: ModelConfig, seq_len: int) -> ModelConfig:
    return cfg.replace(attn_chunk=0, ssm_chunk=max(seq_len, 1))


def moe_flops_factor(cfg) -> float:
    """Active fraction of MLP params per token (MoE top-k vs dense)."""
    if cfg.n_experts:
        return cfg.top_k  # d_ff is per-expert; top_k experts active
    return 1.0


def count_params(cfg: ModelConfig) -> float:
    """Analytical parameter count (excluding embeddings for 6ND)."""
    D, F, Dh = cfg.d_model, cfg.d_ff, cfg.head_dim
    attn = D * (cfg.n_heads + 2 * cfg.n_kv_heads) * Dh \
        + cfg.n_heads * Dh * D
    mlp = 3 * D * F * (cfg.n_experts or 1)
    ssm = 0
    if cfg.family in ("ssm", "hybrid"):
        Din = cfg.d_inner
        R = cfg.dt_rank_eff
        ssm = D * 2 * Din + cfg.d_conv * Din + Din * (R + 2 * cfg.d_state) \
            + R * Din + Din * cfg.d_state + Din * D
    if cfg.family == "ssm":
        per_layer = ssm
    elif cfg.family == "hybrid":
        per_layer = attn + ssm + 3 * D * F
    elif cfg.family == "encdec":
        per_layer = 0  # computed separately below
    else:
        per_layer = attn + 3 * D * F * (cfg.n_experts or 1)
    if cfg.family == "encdec":
        enc = (attn + 3 * D * F) * cfg.n_enc_layers
        dec = (2 * attn + 3 * D * F) * cfg.n_dec_layers
        return enc + dec
    return per_layer * cfg.n_layers


def active_params(cfg: ModelConfig) -> float:
    """N_active for MODEL_FLOPS = 6·N_active·D (MoE counts top_k experts)."""
    D, F = cfg.d_model, cfg.d_ff
    total = count_params(cfg)
    if cfg.n_experts:
        total -= 3 * D * F * cfg.n_experts * cfg.n_layers
        total += 3 * D * F * cfg.top_k * cfg.n_layers
    return total


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """6·N_active·tokens for train; 2·N_active·tokens for forward-only
    (plus attention quadratic term)."""
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    n_act = active_params(cfg)
    mult = 6.0 if shape.kind == "train" else 2.0
    if cfg.family == "encdec" and shape.kind != "train":
        # encoder sees seq_len frames; decoder only its own token budget
        D, F, Dh = cfg.d_model, cfg.d_ff, cfg.head_dim
        attn_p = D * (cfg.n_heads + 2 * cfg.n_kv_heads) * Dh \
            + cfg.n_heads * Dh * D
        enc_p = (attn_p + 3 * D * F) * cfg.n_enc_layers
        dec_p = (2 * attn_p + 3 * D * F) * cfg.n_dec_layers
        tok_enc = shape.global_batch * shape.seq_len
        tok_dec = shape.global_batch * (min(shape.seq_len, 4096)
                                        if shape.kind == "prefill" else 1)
        flops = mult * (enc_p * tok_enc + dec_p * tok_dec)
        if shape.kind == "prefill":
            tokens = tok_enc  # attention term below keyed to encoder side
    else:
        flops = mult * n_act * tokens
    # attention score/O term
    if cfg.n_heads:
        Dh, Hq = cfg.head_dim, cfg.n_heads
        if shape.kind == "decode":
            kv = shape.seq_len
            att = 4.0 * shape.global_batch * Hq * Dh * kv
            if cfg.family == "hybrid":
                att *= 3.0 / cfg.n_layers  # only global layers see full kv
                att += 4.0 * shape.global_batch * Hq * Dh \
                    * min(cfg.swa_window, kv) * (cfg.n_layers - 3) / cfg.n_layers
            att *= cfg.n_layers if cfg.family != "encdec" else cfg.n_dec_layers * 2
        else:
            att = (mult / 6 * 12.0 if shape.kind == "train" else 4.0) \
                * tokens * shape.seq_len * Hq * Dh / 2
            att *= cfg.n_layers if cfg.family != "encdec" \
                else (cfg.n_enc_layers + 2 * cfg.n_dec_layers)
            if cfg.family == "hybrid":
                w = min(cfg.swa_window, shape.seq_len)
                full = tokens * shape.seq_len / 2
                swa = tokens * w
                att = att / cfg.n_layers * (3 * 1.0 + (cfg.n_layers - 3)
                                            * (swa / full))
        flops += att
    return flops


# ---------------------------------------------------------------------------
# Inputs
# ---------------------------------------------------------------------------

def batch_inputs(cfg: ModelConfig, shape: ShapeConfig, with_labels=True):
    B, S = shape.global_batch, shape.seq_len
    bspec = ax("batch", None)
    if cfg.family == "vlm":
        S_tok = S - cfg.n_patches
        abs_in = {"tokens": SDS((B, S_tok), I32),
                  "patches": SDS((B, cfg.n_patches, cfg.d_model), BF16)}
        specs = {"tokens": bspec, "patches": ax("batch", None, None)}
        if with_labels:
            abs_in["labels"] = SDS((B, S_tok), I32)
            specs["labels"] = bspec
    elif cfg.family == "encdec":
        abs_in = {"frames": SDS((B, S, cfg.d_model), BF16),
                  "tokens": SDS((B, S if shape.kind == "train" else
                                 min(S, 4096)), I32)}
        specs = {"frames": ax("batch", None, None), "tokens": bspec}
        if with_labels:
            abs_in["labels"] = SDS(abs_in["tokens"].shape, I32)
            specs["labels"] = bspec
    else:
        abs_in = {"tokens": SDS((B, S), I32)}
        specs = {"tokens": bspec}
        if with_labels:
            abs_in["labels"] = SDS((B, S), I32)
            specs["labels"] = bspec
    return abs_in, specs


def abstract_cache(cfg: ModelConfig, shape: ShapeConfig):
    B = shape.global_batch
    cache = jax.eval_shape(lambda: Mdl.init_cache(cfg, B, shape.seq_len))
    specs = Mdl.cache_specs(cfg, long_context=(shape.name == "long_500k"))
    return cache, specs


# ---------------------------------------------------------------------------
# Step builders
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, adam: Opt.AdamConfig):
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: Mdl.loss_fn(cfg, p, batch))(params)
        params, opt_state, metrics = Opt.adam_update(params, grads,
                                                     opt_state, adam)
        return params, opt_state, loss
    return train_step


def _train_unit(cfg_cost, layer_fwd):
    """vjp of one remat'd layer body — forward + recompute + backward."""
    def unit(p_layer, x):
        f = Lyr.maybe_remat(lambda pp, xx: layer_fwd(pp, xx),
                            cfg_cost.remat_policy)
        y, vjp = jax.vjp(f, p_layer, x)
        return vjp(jnp.ones_like(y))
    return unit


def _layer_template_and_specs(cfg, fam_key):
    tpl = Mdl.build_templates(cfg)
    if fam_key in ("layers", "enc", "dec"):
        sub = tpl[fam_key]
    else:  # hybrid groups
        sub = tpl[fam_key]
    # strip the stacked leading dim
    def strip(t):
        return Lyr.TSpec(t.shape[1:], t.axes[1:], t.scale)
    sub1 = jax.tree.map(strip, sub, is_leaf=lambda x: isinstance(x, Lyr.TSpec))
    return (Lyr.abstract_from_template(sub1, jnp.dtype(cfg.param_dtype)),
            Lyr.specs_from_template(sub1))


def make_cost_units(cfg: ModelConfig, shape: ShapeConfig) -> list:
    """Per-layer bodies + multipliers for scan-count correction."""
    cfgc = cost_variant(cfg, shape.seq_len)
    B, S = shape.global_batch, shape.seq_len
    kind = shape.kind
    x_abs = SDS((B, S if kind != "decode" else 1, cfg.d_model), BF16)
    x_spec = ax("batch", None, None)
    units = []
    pos = jnp.arange(S)

    def add(name, mult, fn, args, shardings):
        if mult > 0:
            units.append(CostUnit(name, mult, fn, args, shardings))

    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        p_abs, p_spec = _layer_template_and_specs(cfg, "layers")
        if kind == "train":
            fwd = lambda pp, xx: Mdl.dense_layer_fwd(cfgc, pp, xx, pos)[0]
            add("layer", cfg.n_layers - 1, _train_unit(cfgc, fwd),
                (p_abs, x_abs), (p_spec, x_spec))
        elif kind == "prefill":
            fn = lambda pp, xx: Mdl.dense_layer_fwd(cfgc, pp, xx, pos)[0]
            add("layer", cfg.n_layers - 1, fn, (p_abs, x_abs),
                (p_spec, x_spec))
        else:  # decode
            cache, cspecs = abstract_cache(cfg, shape)
            kc = SDS(cache["k"].shape[1:], cache["k"].dtype)
            vc = SDS(cache["v"].shape[1:], cache["v"].dtype)
            kspec = P(*cspecs["k"][1:])

            def dec_fn(pp, xx, kc, vc):
                posn = jnp.asarray(S - 1, I32)
                h = Lyr.rms_norm(xx, pp["ln1"], cfgc.norm_eps)
                o, kc, vc, _ = Mdl._decode_attn_layer(cfgc, pp, h, kc, vc,
                                                      posn, posn + 1)
                xx = xx + Lyr.attn_out(pp["attn"], o, cfgc)
                h = Lyr.rms_norm(xx, pp["ln2"], cfgc.norm_eps)
                if "router" in pp["mlp"]:
                    from repro.models import moe as Moe
                    xx = xx + Moe.moe_apply(pp["mlp"], h,
                                            cfgc.replace(moe_group=1))
                else:
                    xx = xx + Lyr.mlp_apply(pp["mlp"], h, cfgc)
                return xx, kc, vc
            add("layer", cfg.n_layers - 1, dec_fn, (p_abs, x_abs, kc, vc),
                (p_spec, x_spec, kspec, kspec))

    elif fam == "ssm":
        p_abs, p_spec = _layer_template_and_specs(cfg, "layers")
        if kind == "train":
            fwd = lambda pp, xx: Mdl.ssm_layer_fwd(cfgc, pp, xx)[0]
            add("layer", cfg.n_layers - 1, _train_unit(cfgc, fwd),
                (p_abs, x_abs), (p_spec, x_spec))
        elif kind == "prefill":
            fn = lambda pp, xx: Mdl.ssm_layer_fwd(cfgc, pp, xx)[0]
            add("layer", cfg.n_layers - 1, fn, (p_abs, x_abs),
                (p_spec, x_spec))
        else:
            cache, cspecs = abstract_cache(cfg, shape)
            h = SDS(cache["h"].shape[1:], cache["h"].dtype)
            cv = SDS(cache["conv"].shape[1:], cache["conv"].dtype)

            def dec_fn(pp, xx, h0, c0):
                hh = Lyr.rms_norm(xx, pp["ln1"], cfgc.norm_eps)
                y, st = M.mamba_step(pp["ssm"], hh, cfgc, (h0, c0))
                return xx + y, st
            add("layer", cfg.n_layers - 1, dec_fn, (p_abs, x_abs, h, cv),
                (p_spec, x_spec, P(*cspecs["h"][1:]), P(*cspecs["conv"][1:])))

    elif fam == "hybrid":
        g_ids, spans = Mdl.hybrid_split(cfg)
        nW = cfg.n_layers - len(g_ids)
        n_spans = sum(1 for s in spans if s > 0)
        p_abs, p_spec = _layer_template_and_specs(cfg, "swa")
        if kind == "train":
            fwd = lambda pp, xx: Mdl.hybrid_layer_fwd(
                cfgc, pp, xx, pos, window=cfg.swa_window)[0]
            add("swa_layer", nW - n_spans, _train_unit(cfgc, fwd),
                (p_abs, x_abs), (p_spec, x_spec))
        elif kind == "prefill":
            fn = lambda pp, xx: Mdl.hybrid_layer_fwd(
                cfgc, pp, xx, pos, window=cfg.swa_window)[0]
            add("swa_layer", nW - n_spans, fn, (p_abs, x_abs),
                (p_spec, x_spec))
        else:
            cache, cspecs = abstract_cache(cfg, shape)
            args = tuple(SDS(cache[k].shape[1:], cache[k].dtype)
                         for k in ("kw", "vw", "wpos", "hw", "convw"))
            sh = tuple(P(*cspecs[k][1:])
                       for k in ("kw", "vw", "wpos", "hw", "convw"))

            def dec_fn(pp, xx, kc, vc, wp, h0, c0):
                posn = jnp.asarray(S - 1, I32)
                hh = Lyr.rms_norm(xx, pp["ln1"], cfgc.norm_eps)
                o, kc, vc, wp = Mdl._decode_attn_layer(
                    cfgc, pp, hh, kc, vc, posn, posn + 1,
                    window=cfg.swa_window, wpos=wp)
                ao = Lyr.attn_out(pp["attn"], o, cfgc)
                so, st = M.mamba_step(pp["ssm"], hh, cfgc, (h0, c0))
                fused = 0.5 * (Lyr.rms_norm(ao, pp["ln_attn"], cfgc.norm_eps)
                               + Lyr.rms_norm(so, pp["ln_ssm"], cfgc.norm_eps))
                xx = xx + fused
                h2 = Lyr.rms_norm(xx, pp["ln2"], cfgc.norm_eps)
                xx = xx + Lyr.mlp_apply(pp["mlp"], h2, cfgc)
                return xx, kc, vc, wp, st
            add("swa_layer", nW - 1, dec_fn, (p_abs, x_abs) + args,
                (p_spec, x_spec) + sh)

    elif fam == "encdec":
        e_abs, e_spec = _layer_template_and_specs(cfg, "enc")
        d_abs, d_spec = _layer_template_and_specs(cfg, "dec")
        mem_abs = SDS((B, S, cfg.d_model), BF16)
        if kind in ("train", "prefill"):
            Sd = S if kind == "train" else min(S, 4096)
            xd_abs = SDS((B, Sd, cfg.d_model), BF16)
            posd = jnp.arange(Sd)
            enc_fn = lambda pp, xx: Mdl.enc_layer_fwd(cfgc, pp, xx, pos)
            dec_fn = lambda pp, xx, mm: Mdl.dec_layer_fwd(
                cfgc, pp, xx, mm, posd, pos)[0]
            if kind == "train":
                add("enc_layer", cfg.n_enc_layers - 1,
                    _train_unit(cfgc, enc_fn), (e_abs, x_abs),
                    (e_spec, x_spec))

                def dec_unit(pp, xx, mm):
                    f = Lyr.maybe_remat(lambda p2, x2: dec_fn(p2, x2, mm),
                                        cfgc.remat_policy)
                    y, vjp = jax.vjp(f, pp, xx)
                    return vjp(jnp.ones_like(y))
                add("dec_layer", cfg.n_dec_layers - 1, dec_unit,
                    (d_abs, xd_abs, mem_abs), (d_spec, x_spec, x_spec))
            else:
                add("enc_layer", cfg.n_enc_layers - 1, enc_fn,
                    (e_abs, x_abs), (e_spec, x_spec))
                add("dec_layer", cfg.n_dec_layers - 1, dec_fn,
                    (d_abs, xd_abs, mem_abs), (d_spec, x_spec, x_spec))
        else:
            cache, cspecs = abstract_cache(cfg, shape)
            args = tuple(SDS(cache[k].shape[1:], cache[k].dtype)
                         for k in ("k", "v", "ck", "cv"))
            sh = tuple(P(*cspecs[k][1:]) for k in ("k", "v", "ck", "cv"))

            def dec_fn(pp, xx, kc, vc, ck, cv):
                posn = jnp.asarray(min(S, 4096) - 1, I32)
                h = Lyr.rms_norm(xx, pp["ln1"], cfgc.norm_eps)
                o, kc, vc, _ = Mdl._decode_attn_layer(cfgc, pp, h, kc, vc,
                                                      posn, posn + 1)
                xx = xx + Lyr.attn_out(pp["attn"], o, cfgc)
                h = Lyr.rms_norm(xx, pp["lnx"], cfgc.norm_eps)
                qx, _, _ = Lyr.attn_qkv(pp["xattn"], h, cfgc, posn[None, None])
                ox = Lyr.decode_attention(qx, ck, cv, jnp.asarray(S))
                xx = xx + Lyr.attn_out(pp["xattn"], ox, cfgc)
                h = Lyr.rms_norm(xx, pp["ln2"], cfgc.norm_eps)
                xx = xx + Lyr.mlp_apply(pp["mlp"], h, cfgc)
                return xx, kc, vc
            add("dec_layer", cfg.n_dec_layers - 1, dec_fn,
                (d_abs, x_abs) + args, (d_spec, x_spec) + sh)
    return units


def build_bundle(arch: str, shape_name: str) -> StepBundle:
    shape = SHAPES[shape_name]
    cfg = tune_config(load_config(arch), shape)
    notes = (f"residual_shard={cfg.residual_shard} attn_chunk={cfg.attn_chunk}"
             f" remat={cfg.remat_policy}")

    if shape.kind == "train":
        adam = Opt.AdamConfig(
            moment_dtype="bfloat16" if count_params(cfg) > 5e10 else "float32")
        p_abs = Mdl.abstract_params(cfg)
        p_spec = Mdl.param_specs(cfg)
        opt_abs = jax.eval_shape(lambda p: Opt.init_adam(p, adam), p_abs)
        opt_spec = Opt.AdamState(P(), p_spec, p_spec)
        b_abs, b_spec = batch_inputs(cfg, shape, with_labels=True)
        fn = make_train_step(cfg, adam)
        return StepBundle(
            arch, shape_name, "train", fn,
            (p_abs, opt_abs, b_abs), (p_spec, opt_spec, b_spec),
            (p_spec, opt_spec, P()), (0, 1),
            make_cost_units(cfg, shape), model_flops(cfg, shape), notes)

    # serving: params in bf16
    p_abs = Mdl.abstract_params(cfg, dtype="bfloat16")
    p_spec = Mdl.param_specs(cfg)
    if shape.kind == "prefill":
        b_abs, b_spec = batch_inputs(cfg, shape, with_labels=False)
        cache_abs, cache_spec = abstract_cache(cfg, shape)

        def prefill_fn(params, batch):
            return Mdl.prefill(cfg, params, batch, shape.seq_len)
        return StepBundle(
            arch, shape_name, "prefill", prefill_fn,
            (p_abs, b_abs), (p_spec, b_spec), (P(), cache_spec), (),
            make_cost_units(cfg, shape), model_flops(cfg, shape), notes)

    # decode
    cache_abs, cache_spec = abstract_cache(cfg, shape)
    tok_abs = SDS((shape.global_batch, 1), I32)

    def decode_fn(params, cache, token):
        return Mdl.decode_step(cfg, params, cache, token)
    return StepBundle(
        arch, shape_name, "decode", decode_fn,
        (p_abs, cache_abs, tok_abs), (p_spec, cache_spec, ax("batch", None)),
        (P(), cache_spec), (1,),
        make_cost_units(cfg, shape), model_flops(cfg, shape), notes)


# ---------------------------------------------------------------------------
# SVFusion (paper's own architecture) cells
# ---------------------------------------------------------------------------

SVF_SHAPES = {
    "search_10k": dict(n=1_000_000_000, dim=96, degree=32, batch=10240,
                       cache_per_chip=131072),   # Deep1B
    "search_1k": dict(n=200_000_000, dim=100, degree=32, batch=1024,
                      cache_per_chip=131072),    # MSTuring-200M
}


def build_svfusion_bundle(shape_name: str, mesh) -> StepBundle:
    from repro.core.distributed import (analytical_search_flops,
                                        make_distributed_search,
                                        shard_index_arrays)
    from repro.core.types import SearchParams
    p = SVF_SHAPES[shape_name]
    sp = SearchParams(k=10, pool=64, max_iters=64)
    # capacity tier shards over EVERY mesh axis (HBM feasibility at 1B
    # scale); queries replicated, per-shard top-k merged over all axes
    data_axes = tuple(mesh.axis_names)
    n_shards = int(mesh.devices.size)
    idx = shard_index_arrays(p["n"], p["dim"], p["degree"], n_shards,
                             p["cache_per_chip"])
    queries = SDS((p["batch"], p["dim"]), F32)
    key = SDS((2,), jnp.uint32)
    step = make_distributed_search(mesh, sp, data_axes=data_axes,
                                   query_axis=None)
    dspec = data_axes if len(data_axes) > 1 else data_axes[0]
    in_sh = ({k: (P(dspec, None) if v.ndim == 2 else P(dspec))
              for k, v in idx.items()},
             P(None, None), P())
    return StepBundle(
        "svfusion_deep1b" if shape_name == "search_10k" else "svfusion_msturing",
        shape_name, "search", step, (idx, queries, key), in_sh,
        None, (),
        # replicated-query scheme: every shard beam-searches its partition
        # for the whole batch, so useful work scales with n_shards
        [], analytical_search_flops(sp, p["batch"], p["dim"],
                                    p["degree"]) * n_shards,
        f"distributed beam search, {n_shards} shards, queries replicated")
