"""Version-guarded JAX compatibility layer.

The repo targets the mesh/sharding API of recent JAX, but the pinned
environment ships JAX 0.4.37 where several entry points do not exist:

* ``jax.sharding.AxisType`` / ``axis_types=`` on ``jax.make_mesh``
* ``jax.sharding.get_abstract_mesh`` (the active-mesh query)
* ``jax.set_mesh`` (the mesh context manager)
* ``jax.shard_map`` (still ``jax.experimental.shard_map`` with
  ``check_rep`` instead of ``check_vma``)

Every helper here resolves to the native API when present and otherwise
falls back to the 0.4.37 equivalent, so call sites never branch on
versions themselves. No other module should touch these APIs directly.
"""
from __future__ import annotations

import contextlib
from typing import Optional, Sequence

import jax

_HAS_AXIS_TYPE = hasattr(jax.sharding, "AxisType")
_HAS_GET_ABSTRACT_MESH = hasattr(jax.sharding, "get_abstract_mesh")
_HAS_SET_MESH = hasattr(jax, "set_mesh")
_HAS_SHARD_MAP = hasattr(jax, "shard_map")


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str],
              *, auto_axes: bool = True):
    """``jax.make_mesh`` with Auto axis types when the API supports them.

    On 0.4.37 there is no ``axis_types`` parameter (every axis behaves as
    the legacy auto mode), so the argument is simply dropped.
    """
    if _HAS_AXIS_TYPE and auto_axes:
        return jax.make_mesh(
            tuple(axis_shapes), tuple(axis_names),
            axis_types=(jax.sharding.AxisType.Auto,) * len(tuple(axis_names)))
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names))


def get_abstract_mesh():
    """The mesh active in the current context, or None.

    Recent JAX: ``jax.sharding.get_abstract_mesh()``. 0.4.37: the physical
    mesh installed by the ``with mesh:`` context manager (it exposes the
    same ``empty`` / ``axis_names`` / ``axis_sizes`` surface the callers
    use). Returns None when no mesh is active so callers can uniformly
    test ``mesh is None or mesh.empty``.
    """
    if _HAS_GET_ABSTRACT_MESH:
        return jax.sharding.get_abstract_mesh()
    from jax._src import mesh as mesh_lib
    m = mesh_lib.thread_resources.env.physical_mesh
    return None if m.empty else m


@contextlib.contextmanager
def use_mesh(mesh):
    """Context manager activating ``mesh`` (``jax.set_mesh`` analogue).

    On 0.4.37 the legacy ``with mesh:`` form installs the mesh into the
    thread's resource env, which is what pjit/shard_map consult.
    """
    if _HAS_SET_MESH:
        with jax.set_mesh(mesh):
            yield mesh
    else:
        with mesh:
            yield mesh


def axis_size(axis_name: str):
    """``jax.lax.axis_size`` with the pre-0.5 ``psum(1, axis)`` fallback
    (constant-folds to the static mesh axis size inside shard_map/pmap)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def resolve_shardings(tree):
    """Make a PartitionSpec tree acceptable to ``jax.jit`` shardings args.

    Recent JAX accepts raw PartitionSpecs under an active mesh; 0.4.37
    requires concrete ``NamedSharding``s, so specs are bound to the mesh
    installed by :func:`use_mesh`. Must be called inside the mesh context.
    """
    if _HAS_SET_MESH or tree is None:
        return tree
    mesh = get_abstract_mesh()
    if mesh is None:
        return tree
    from jax.sharding import NamedSharding, PartitionSpec
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s) if isinstance(s, PartitionSpec)
        else s,
        tree, is_leaf=lambda s: isinstance(s, PartitionSpec))


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: Optional[bool] = None):
    """``jax.shard_map`` with the ``check_vma``/``check_rep`` rename bridged."""
    if _HAS_SHARD_MAP:
        kw = {} if check_vma is None else {"check_vma": check_vma}
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map
    kw = {} if check_vma is None else {"check_rep": check_vma}
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kw)
