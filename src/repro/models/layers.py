"""Core model building blocks: templates, norms, RoPE, attention, SwiGLU.

Pure-JAX (no flax). Parameters are nested dicts of arrays. Every family
module builds a *template* — a nested dict of ``TSpec(shape, axes, scale)`` —
from which both the init'd params and the PartitionSpec tree are derived
(single source of truth for shapes and shardings).
"""
from __future__ import annotations

import math
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.sharding import ax, constrain, weight_gather


class TSpec(NamedTuple):
    shape: tuple
    axes: tuple            # logical axis names (None = replicated)
    scale: float = 0.02    # normal init stddev; 0 -> zeros; -1 -> ones


def init_from_template(key, template, dtype=jnp.float32):
    leaves, treedef = jax.tree.flatten(template, is_leaf=lambda x: isinstance(x, TSpec))
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, t in zip(keys, leaves):
        if t.scale == 0.0:
            out.append(jnp.zeros(t.shape, dtype))
        elif t.scale == -1.0:
            out.append(jnp.ones(t.shape, dtype))
        else:
            out.append((jax.random.normal(k, t.shape) * t.scale).astype(dtype))
    return jax.tree.unflatten(treedef, out)


def specs_from_template(template):
    return jax.tree.map(lambda t: ax(*t.axes), template,
                        is_leaf=lambda x: isinstance(x, TSpec))


def abstract_from_template(template, dtype=jnp.float32):
    return jax.tree.map(lambda t: jax.ShapeDtypeStruct(t.shape, dtype), template,
                        is_leaf=lambda x: isinstance(x, TSpec))


def res_constrain(cfg, x):
    """Residual-stream sharding constraint (ModelConfig.residual_shard)."""
    if cfg.residual_shard == "seq":
        return constrain(x, "batch", "tensor", None)
    if cfg.residual_shard == "dmodel":
        return constrain(x, "batch", None, "tensor")
    return constrain(x, "batch", None, None)


def sp_gather(cfg, h):
    """Block-boundary gather: collect the seq- or dmodel-sharded activation
    ONCE so the q/k/v (or in_proj/gate/up) projections share a single
    all-gather instead of re-gathering (or partial-sum all-reducing) per
    matmul (§Perf: 3x fewer activation collectives)."""
    if cfg.residual_shard in ("seq", "dmodel"):
        return constrain(h, "batch", None, None)
    return h


# ---------------------------------------------------------------------------
# Norms / RoPE
# ---------------------------------------------------------------------------

def rms_norm(x, weight, eps=1e-5):
    # fp32-ACCUMULATING einsum over bf16 operands: variance is exact-enough
    # without ever materializing a full fp32 copy of the residual stream.
    # (A plain x.astype(f32) here makes XLA sink the convert into the remat
    # saved-activation stack, doubling its bytes — EXPERIMENTS.md §Perf.)
    sq = jnp.einsum("...d,...d->...", x, x,
                    preferred_element_type=jnp.float32)
    var = (sq / x.shape[-1])[..., None]
    inv = (jax.lax.rsqrt(var + eps)).astype(x.dtype)
    return x * inv * weight.astype(x.dtype)


def rope_freqs(positions, head_dim, theta):
    """positions [...], returns (cos, sin) of shape [..., head_dim//2]."""
    half = head_dim // 2
    inv = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x [B,S,H,D]; cos/sin [B,S,half] or [S,half]."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    x1, x2 = jnp.split(x, 2, axis=-1)
    if cos.ndim == 2:       # [S, half] -> [1, S, 1, half]
        cos, sin = cos[None, :, None, :], sin[None, :, None, :]
    elif cos.ndim == 3:     # [B, S, half] -> [B, S, 1, half]
        cos, sin = cos[:, :, None, :], sin[:, :, None, :]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                           axis=-1).astype(dt)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def _mask_bias(qpos, kpos, *, causal, window, kv_len=None):
    """Additive mask bias [*, S, T] from absolute positions."""
    q = qpos[..., :, None]
    k = kpos[..., None, :]
    cond = jnp.ones(jnp.broadcast_shapes(q.shape, k.shape), dtype=bool)
    if causal:
        cond = cond & (k <= q)
    if window:
        cond = cond & (k > q - window)
    if kv_len is not None:
        cond = cond & (k < kv_len)
    return jnp.where(cond, 0.0, -1e30).astype(jnp.float32)


def _attn_core(q, k, v, bias):
    """q [B,S,H,D]; k,v [B,T,H,D]; bias broadcastable to [B,1,S,T] (fp32)."""
    scale = 1.0 / math.sqrt(q.shape[-1])
    scores = jnp.einsum("bshd,bthd->bhst", q, k,
                        preferred_element_type=jnp.float32) * scale
    scores = scores + bias
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhst,bthd->bshd", probs, v)
    return out


def repeat_kv(k, n_rep):
    if n_rep == 1:
        return k
    return jnp.repeat(k, n_rep, axis=2)


def attention(q, k, v, *, causal=True, q_offset=0, window=0, kv_len=None,
              chunk=0):
    """Multi-head attention with GQA repeat, optional sliding window and
    query chunking (memory control for long prefill).

    q [B,S,Hq,D]; k,v [B,T,Hkv,D]. q_offset: absolute position of q[0]
    (scalar or [B]). kv_len: valid KV length (scalar or [B]) for decode.
    """
    B, S, Hq, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    k = repeat_kv(k, Hq // Hkv)
    v = repeat_kv(v, Hq // Hkv)
    q_offset = jnp.asarray(q_offset)
    kv_len_arr = None if kv_len is None else jnp.asarray(kv_len)

    def block(qc, off):
        Sc = qc.shape[1]
        qpos = off[..., None] + jnp.arange(Sc) if off.ndim else off + jnp.arange(Sc)
        kpos = jnp.arange(T)
        if qpos.ndim == 1:
            bias = _mask_bias(qpos, kpos, causal=causal, window=window,
                              kv_len=kv_len_arr if (kv_len_arr is None or kv_len_arr.ndim == 0) else None)
            bias = bias[None, None]
        else:  # per-batch offsets
            bias = jax.vmap(lambda qp: _mask_bias(qp, kpos, causal=causal,
                                                  window=window))(qpos)[:, None]
        if kv_len_arr is not None and kv_len_arr.ndim == 1:
            bias = bias + jnp.where(kpos[None, None, None, :]
                                    < kv_len_arr[:, None, None, None], 0.0, -1e30)
        return _attn_core(qc, k, v, bias)

    if chunk and S > chunk and S % chunk == 0:
        n = S // chunk
        qs = q.reshape(B, n, chunk, Hq, D).transpose(1, 0, 2, 3, 4)

        def body(_, args):
            i, qc = args
            return _, block(qc, q_offset + i * chunk)

        _, out = jax.lax.scan(body, None, (jnp.arange(n), qs))
        return out.transpose(1, 0, 2, 3, 4).reshape(B, S, Hq, D)
    return block(q, q_offset)


def cross_attention(q, k, v):
    """Bidirectional cross-attention (whisper decoder -> encoder memory)."""
    return attention(q, k, v, causal=False)


def decode_attention(q, k_cache, v_cache, kv_len, *, window=0):
    """Single-token decode attention. q [B,1,Hq,D]; caches [B,T,Hkv,D].

    The KV cache sequence dim may be sharded (logical ``kv_seq``); the
    softmax/O-contraction over the sharded T lowers to partial reductions +
    all-reduce (flash-decoding-style combine) rather than a KV all-gather —
    verified in the dry-run HLO.
    """
    return attention(q, k_cache, v_cache, causal=False, window=window,
                     q_offset=jnp.asarray(kv_len) - 1 if window else 0,
                     kv_len=kv_len)


# ---------------------------------------------------------------------------
# Attention block (projections + RoPE + attention)
# ---------------------------------------------------------------------------

def attn_template(cfg, stacked: Optional[int] = None):
    D, Hq, Hkv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    L = (stacked,) if stacked else ()
    LN = (None,) if stacked else ()
    s = 0.02
    t = {
        "wq": TSpec(L + (D, Hq * Dh), LN + ("fsdp", "tensor"), s),
        "wk": TSpec(L + (D, Hkv * Dh), LN + ("fsdp", "tensor"), s),
        "wv": TSpec(L + (D, Hkv * Dh), LN + ("fsdp", "tensor"), s),
        "wo": TSpec(L + (Hq * Dh, D), LN + ("tensor", "fsdp"), s / math.sqrt(2 * cfg.n_layers)),
    }
    if cfg.qk_norm:
        t["q_norm"] = TSpec(L + (Dh,), LN + (None,), -1.0)
        t["k_norm"] = TSpec(L + (Dh,), LN + (None,), -1.0)
    return t


def attn_qkv(p, x, cfg, positions):
    """Project + RoPE. Returns q [B,S,Hq,D], k,v [B,S,Hkv,D]."""
    B, S, _ = x.shape
    Hq, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = x.dtype
    wq = weight_gather(cfg, p["wq"].astype(dt), ("fsdp", "tensor"))
    wk = weight_gather(cfg, p["wk"].astype(dt), ("fsdp", "tensor"))
    wv = weight_gather(cfg, p["wv"].astype(dt), ("fsdp", "tensor"))
    q = (x @ wq).reshape(B, S, Hq, Dh)
    k = (x @ wk).reshape(B, S, Hkv, Dh)
    v = (x @ wv).reshape(B, S, Hkv, Dh)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    cos, sin = rope_freqs(positions, Dh, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    q = constrain(q, "batch", None, "tensor", None)
    return q, k, v


def attn_out(p, o, cfg):
    B, S = o.shape[:2]
    wo = weight_gather(cfg, p["wo"].astype(o.dtype), ("tensor", "fsdp"))
    y = o.reshape(B, S, cfg.n_heads * cfg.head_dim) @ wo
    return res_constrain(cfg, y)


# ---------------------------------------------------------------------------
# MLP (SwiGLU)
# ---------------------------------------------------------------------------

def mlp_template(cfg, stacked: Optional[int] = None, d_ff=None):
    D, F = cfg.d_model, d_ff or cfg.d_ff
    L = (stacked,) if stacked else ()
    LN = (None,) if stacked else ()
    return {
        "w_gate": TSpec(L + (D, F), LN + ("fsdp", "tensor"), 0.02),
        "w_up": TSpec(L + (D, F), LN + ("fsdp", "tensor"), 0.02),
        "w_down": TSpec(L + (F, D), LN + ("tensor", "fsdp"),
                        0.02 / math.sqrt(2 * cfg.n_layers)),
    }


def mlp_apply(p, x, cfg=None):
    dt = x.dtype
    if cfg is not None:
        wg = weight_gather(cfg, p["w_gate"].astype(dt), ("fsdp", "tensor"))
        wu = weight_gather(cfg, p["w_up"].astype(dt), ("fsdp", "tensor"))
        wd = weight_gather(cfg, p["w_down"].astype(dt), ("tensor", "fsdp"))
    else:
        wg, wu, wd = (p[k].astype(dt) for k in ("w_gate", "w_up", "w_down"))
    h = jax.nn.silu(x @ wg) * (x @ wu)
    h = constrain(h, "batch", None, "tensor")
    y = h @ wd
    return res_constrain(cfg, y) if cfg is not None else constrain(
        y, "batch", None, None)


# ---------------------------------------------------------------------------
# Embedding / head / loss
# ---------------------------------------------------------------------------

def embed_template(cfg):
    V, D = cfg.padded_vocab, cfg.d_model
    t = {
        "embed": TSpec((V, D), ("tensor", "fsdp"), 0.02),
        "final_norm": TSpec((D,), (None,), -1.0),
    }
    if not cfg.tie_embeddings:
        t["head"] = TSpec((D, V), ("fsdp", "tensor"), 0.02)
    return t


def embed_tokens(p, tokens, cfg, dtype):
    emb = jnp.take(p["embed"].astype(dtype), tokens, axis=0)
    return constrain(emb, "batch", None, None)


def lm_logits(p, x, cfg):
    w = p["head"] if not cfg.tie_embeddings else p["embed"].T
    logits = x @ w.astype(x.dtype)
    return constrain(logits, "batch", None, "tensor")


def softmax_xent(logits, labels, mask=None):
    """logits [B,S,V] (V may be sharded), labels [B,S]. Mean over tokens."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    picked = jnp.sum(logits * onehot, axis=-1)
    loss = lse - picked
    if mask is not None:
        loss = loss * mask
        return loss.sum() / jnp.maximum(mask.sum(), 1)
    return loss.mean()


# ---------------------------------------------------------------------------
# Remat
# ---------------------------------------------------------------------------

def maybe_remat(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
