"""Logical-axis sharding rules.

Models annotate tensors with *logical* axis names; this module resolves them
to mesh axes present in the current abstract mesh. Rules are swappable via
``rules_override`` — the primary hillclimbing lever for the §Perf loop.

Logical axes:
    batch    activation batch dim            -> ("pod","data")
    fsdp     weight d_model (ZeRO/FSDP) dim  -> ("pod","data")
    tensor   heads / mlp / vocab TP dim      -> ("model",)
    kv_seq   sharded KV-cache sequence dim   -> ("model",)   [decode]
    kv_seq_long  long-context KV sequence    -> ("data","model") [long_500k]
    expert   MoE expert dim                  -> ()  (replicated axis; ff uses tensor)
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence

import jax
from jax.sharding import PartitionSpec as P

from repro import compat

DEFAULT_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "fsdp": ("pod", "data"),
    "tensor": ("model",),
    "kv_seq": ("model",),
    "kv_seq_long": ("data", "model"),
    "expert": (),
}

_local = threading.local()


def _rules() -> dict[str, tuple[str, ...]]:
    return getattr(_local, "rules", DEFAULT_RULES)


@contextlib.contextmanager
def rules_override(**kw):
    """Temporarily replace logical->mesh rules (hillclimbing)."""
    old = _rules()
    new = dict(old)
    for k, v in kw.items():
        new[k] = tuple(v) if v else ()
    _local.rules = new
    try:
        yield
    finally:
        _local.rules = old


def mesh_axis_names() -> tuple[str, ...]:
    mesh = compat.get_abstract_mesh()
    if mesh is None or mesh.empty:
        return ()
    return tuple(mesh.axis_names)


def resolve(logical: Optional[str]) -> Optional[tuple[str, ...]]:
    """Resolve one logical name to mesh axes present in the current mesh."""
    if logical is None:
        return None
    present = set(mesh_axis_names())
    axes = tuple(a for a in _rules().get(logical, ()) if a in present)
    return axes or None

def ax(*logicals: Optional[str]) -> P:
    """Build a PartitionSpec from logical names (None = replicated dim)."""
    out = []
    for name in logicals:
        r = resolve(name)
        if r is None:
            out.append(None)
        elif len(r) == 1:
            out.append(r[0])
        else:
            out.append(r)
    return P(*out)


def constrain(x, *logicals: Optional[str]):
    """with_sharding_constraint using logical names; no-op without a mesh."""
    if not mesh_axis_names():
        return x
    return jax.lax.with_sharding_constraint(x, ax(*logicals))


def weight_gather(cfg, w, axes):
    """Constrain a weight gathered over its fsdp dims (tensor dims kept)
    when cfg.gather_weights — steers XLA to all-gather-weights instead of
    partial-matmul + huge activation all-reduces on token-heavy steps."""
    if not getattr(cfg, "gather_weights", False) or not mesh_axis_names():
        return w
    return jax.lax.with_sharding_constraint(
        w, ax(*[a if a == "tensor" else None for a in axes]))


def axis_size(logical: str) -> int:
    mesh = compat.get_abstract_mesh()
    if mesh is None or mesh.empty:
        return 1
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    n = 1
    for a in _rules().get(logical, ()):
        n *= sizes.get(a, 1)
    return n
