"""Model assembly for all assigned architecture families.

Families: dense | moe | ssm | hybrid | encdec | vlm.

* Layer stacks are ``lax.scan``-ed over stacked parameters (compact HLO,
  fast 512-device compiles); hybrid models unroll their 3 global-attention
  layers and scan the sliding-window spans.
* ``prefill`` returns a KV/SSM cache; ``decode_step`` consumes + updates it.
* ``cost_units`` exposes per-layer bodies + trip multipliers so the roofline
  extractor can correct for scan bodies being counted once by
  ``cost_analysis`` (see DESIGN.md §8).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import layers as Lyr
from repro.models import mamba as M
from repro.models import moe as Moe
from repro.models.layers import (TSpec, attention, attn_out, attn_qkv,
                                 attn_template, decode_attention,
                                 embed_template, embed_tokens, lm_logits,
                                 maybe_remat, mlp_apply, mlp_template,
                                 rms_norm, softmax_xent)
from repro.models.sharding import ax, constrain

# ---------------------------------------------------------------------------
# Templates
# ---------------------------------------------------------------------------

def _norm_t(cfg, stacked=None):
    L = (stacked,) if stacked else ()
    LN = (None,) if stacked else ()
    return TSpec(L + (cfg.d_model,), LN + (None,), -1.0)


def _dense_layer_template(cfg, n, with_moe=False):
    t = {
        "attn": attn_template(cfg, stacked=n),
        "ln1": _norm_t(cfg, n),
        "ln2": _norm_t(cfg, n),
    }
    t["mlp"] = Moe.moe_template(cfg, stacked=n) if with_moe \
        else mlp_template(cfg, stacked=n)
    return t


def _hybrid_layer_template(cfg, n):
    return {
        "attn": attn_template(cfg, stacked=n),
        "ssm": M.ssm_template(cfg, stacked=n),
        "ln1": _norm_t(cfg, n),
        "ln2": _norm_t(cfg, n),
        "ln_attn": _norm_t(cfg, n),
        "ln_ssm": _norm_t(cfg, n),
        "mlp": mlp_template(cfg, stacked=n),
    }


def _encdec_layer_templates(cfg):
    enc = {
        "attn": attn_template(cfg, stacked=cfg.n_enc_layers),
        "ln1": _norm_t(cfg, cfg.n_enc_layers),
        "ln2": _norm_t(cfg, cfg.n_enc_layers),
        "mlp": mlp_template(cfg, stacked=cfg.n_enc_layers),
    }
    dec = {
        "attn": attn_template(cfg, stacked=cfg.n_dec_layers),
        "xattn": attn_template(cfg, stacked=cfg.n_dec_layers),
        "ln1": _norm_t(cfg, cfg.n_dec_layers),
        "lnx": _norm_t(cfg, cfg.n_dec_layers),
        "ln2": _norm_t(cfg, cfg.n_dec_layers),
        "mlp": mlp_template(cfg, stacked=cfg.n_dec_layers),
    }
    return enc, dec


def hybrid_split(cfg):
    """(global_layer_ids, swa span sizes). Globals: first / middle / last."""
    L = cfg.n_layers
    g = sorted({0, L // 2, L - 1})
    spans = []
    prev = -1
    for gi in g + [L]:
        spans.append(gi - prev - 1)
        prev = gi
    return g, spans  # len(spans) == len(g)+1 (span before each global + tail)


def build_templates(cfg: ModelConfig):
    fam = cfg.family
    if fam in ("dense", "vlm"):
        return {"tok": embed_template(cfg),
                "layers": _dense_layer_template(cfg, cfg.n_layers)}
    if fam == "moe":
        return {"tok": embed_template(cfg),
                "layers": _dense_layer_template(cfg, cfg.n_layers, with_moe=True)}
    if fam == "ssm":
        return {"tok": embed_template(cfg),
                "layers": {"ssm": M.ssm_template(cfg, stacked=cfg.n_layers),
                           "ln1": _norm_t(cfg, cfg.n_layers)}}
    if fam == "hybrid":
        g, _ = hybrid_split(cfg)
        return {"tok": embed_template(cfg),
                "global": _hybrid_layer_template(cfg, len(g)),
                "swa": _hybrid_layer_template(cfg, cfg.n_layers - len(g))}
    if fam == "encdec":
        enc, dec = _encdec_layer_templates(cfg)
        return {"tok": embed_template(cfg), "enc": enc, "dec": dec,
                "enc_final_norm": _norm_t(cfg)}
    raise ValueError(fam)


def init_params(cfg, key, dtype=None):
    tpl = build_templates(cfg)
    return Lyr.init_from_template(key, tpl, jnp.dtype(dtype or cfg.param_dtype))


def param_specs(cfg):
    return Lyr.specs_from_template(build_templates(cfg))


def abstract_params(cfg, dtype=None):
    return Lyr.abstract_from_template(build_templates(cfg),
                                      jnp.dtype(dtype or cfg.param_dtype))


# ---------------------------------------------------------------------------
# Layer bodies (full-sequence forward; optionally emit KV/state for prefill)
# ---------------------------------------------------------------------------

def dense_layer_fwd(cfg, p, x, positions, *, window=0, collect=False):
    x = Lyr.res_constrain(cfg, x)
    h = Lyr.sp_gather(cfg, rms_norm(x, p["ln1"], cfg.norm_eps))
    q, k, v = attn_qkv(p["attn"], h, cfg, positions)
    o = attention(q, k, v, causal=True, window=window, chunk=cfg.attn_chunk)
    x = x + attn_out(p["attn"], o, cfg)
    h = Lyr.sp_gather(cfg, rms_norm(x, p["ln2"], cfg.norm_eps))
    if cfg.family == "moe" or ("router" in p["mlp"]):
        x = x + Moe.moe_apply(p["mlp"], h, cfg)
    else:
        x = x + mlp_apply(p["mlp"], h, cfg)
    if collect:   # keep stacked prefill KV sharded like the decode cache
        k = constrain(k, "batch", "kv_seq", None, None)
        v = constrain(v, "batch", "kv_seq", None, None)
        return x, (k, v)
    return x, None


def ssm_layer_fwd(cfg, p, x, *, collect=False):
    x = Lyr.res_constrain(cfg, x)
    h = Lyr.sp_gather(cfg, rms_norm(x, p["ln1"], cfg.norm_eps))
    y, state = M.mamba_mixer(p["ssm"], h, cfg)
    x = x + y
    return (x, state) if collect else (x, None)


def hybrid_layer_fwd(cfg, p, x, positions, *, window, collect=False):
    x = Lyr.res_constrain(cfg, x)
    h = Lyr.sp_gather(cfg, rms_norm(x, p["ln1"], cfg.norm_eps))
    q, k, v = attn_qkv(p["attn"], h, cfg, positions)
    o = attention(q, k, v, causal=True, window=window, chunk=cfg.attn_chunk)
    ao = attn_out(p["attn"], o, cfg)
    so, state = M.mamba_mixer(p["ssm"], h, cfg)
    fused = 0.5 * (rms_norm(ao, p["ln_attn"], cfg.norm_eps)
                   + rms_norm(so, p["ln_ssm"], cfg.norm_eps))
    x = x + fused
    h2 = Lyr.sp_gather(cfg, rms_norm(x, p["ln2"], cfg.norm_eps))
    x = x + mlp_apply(p["mlp"], h2, cfg)
    if collect:
        k = constrain(k, "batch", "kv_seq", None, None)
        v = constrain(v, "batch", "kv_seq", None, None)
        return x, ((k, v), state)
    return x, None


def enc_layer_fwd(cfg, p, x, positions):
    x = Lyr.res_constrain(cfg, x)
    h = Lyr.sp_gather(cfg, rms_norm(x, p["ln1"], cfg.norm_eps))
    q, k, v = attn_qkv(p["attn"], h, cfg, positions)
    o = attention(q, k, v, causal=False, chunk=cfg.attn_chunk)
    x = x + attn_out(p["attn"], o, cfg)
    h = Lyr.sp_gather(cfg, rms_norm(x, p["ln2"], cfg.norm_eps))
    return x + mlp_apply(p["mlp"], h, cfg)


def dec_layer_fwd(cfg, p, x, memory, positions, mem_positions, *, collect=False):
    x = Lyr.res_constrain(cfg, x)
    h = Lyr.sp_gather(cfg, rms_norm(x, p["ln1"], cfg.norm_eps))
    q, k, v = attn_qkv(p["attn"], h, cfg, positions)
    o = attention(q, k, v, causal=True, chunk=cfg.attn_chunk)
    x = x + attn_out(p["attn"], o, cfg)
    h = Lyr.sp_gather(cfg, rms_norm(x, p["lnx"], cfg.norm_eps))
    qx, kx, vx = attn_qkv(p["xattn"], h, cfg, positions)
    # cross KV come from encoder memory
    _, mk, mv = attn_qkv(p["xattn"], memory, cfg, mem_positions)
    ox = attention(qx, mk, mv, causal=False, chunk=cfg.attn_chunk)
    x = x + attn_out(p["xattn"], ox, cfg)
    h = Lyr.sp_gather(cfg, rms_norm(x, p["ln2"], cfg.norm_eps))
    x = x + mlp_apply(p["mlp"], h, cfg)
    if collect:
        k = constrain(k, "batch", None, None, None)
        mk = constrain(mk, "batch", "kv_seq", None, None)
        mv = constrain(mv, "batch", "kv_seq", None, None)
        return x, (k, v, mk, mv)
    return x, None


def _scan_layers(body, x, stacked_params, extras=None, remat="full"):
    """Scan ``body(carry, (params_slice, extra_slice))`` over layer dim 0."""
    body = maybe_remat(body, remat)
    xs = (stacked_params, extras) if extras is not None else stacked_params
    y, outs = jax.lax.scan(body, x, xs)
    return y, outs


# ---------------------------------------------------------------------------
# Full forward (training)
# ---------------------------------------------------------------------------

def _cast_once(cfg, tree):
    """Cast layer params to the compute dtype BEFORE the scan: FSDP
    all-gathers then move bf16, not fp32 (EXPERIMENTS.md §Perf)."""
    if not cfg.cast_params_once:
        return tree
    dt = jnp.dtype(cfg.dtype)
    return jax.tree.map(
        lambda a: a.astype(dt) if a.dtype == jnp.float32 else a, tree)


def _lm_trunk(cfg, params, emb, positions, collect=False):
    """Run the layer stack on embeddings. Returns (hidden, cache_parts)."""
    fam = cfg.family
    rp = cfg.remat_policy

    if fam in ("dense", "moe", "vlm"):
        def body(x, p):
            x, kv = dense_layer_fwd(cfg, p, x, positions, collect=collect)
            return x, kv
        x, kvs = _scan_layers(body, emb, _cast_once(cfg, params["layers"]), remat=rp)
        return x, kvs

    if fam == "ssm":
        def body(x, p):
            x, st = ssm_layer_fwd(cfg, p, x, collect=collect)
            return x, st
        x, states = _scan_layers(body, emb, _cast_once(cfg, params["layers"]), remat=rp)
        return x, states

    if fam == "hybrid":
        g_ids, spans = hybrid_split(cfg)
        x = emb
        caches_g, caches_w = [], []
        swa_off = 0

        def swa_body(x, p):
            x, c = hybrid_layer_fwd(cfg, p, x, positions,
                                    window=cfg.swa_window, collect=collect)
            return x, c

        for gi, span in zip(range(len(g_ids)), spans):
            if span > 0:
                sl = jax.tree.map(lambda a: a[swa_off:swa_off + span],
                                  _cast_once(cfg, params["swa"]))
                x, cw = _scan_layers(swa_body, x, sl, remat=rp)
                caches_w.append(cw)
                swa_off += span
            pg = jax.tree.map(lambda a: a[gi], _cast_once(cfg, params["global"]))
            lyr = maybe_remat(
                lambda x, p: hybrid_layer_fwd(cfg, p, x, positions, window=0,
                                              collect=collect), rp)
            x, cg = lyr(x, pg)
            caches_g.append(cg)
        # trailing span
        rem = cfg.n_layers - len(g_ids) - swa_off
        if rem > 0:
            sl = jax.tree.map(lambda a: a[swa_off:], _cast_once(cfg, params["swa"]))
            x, cw = _scan_layers(swa_body, x, sl, remat=rp)
            caches_w.append(cw)
        return x, (caches_g, caches_w)

    raise ValueError(fam)


def forward_lm(cfg, params, tokens, patches=None):
    """Training/prefill forward for decoder-only families. Returns logits."""
    dt = jnp.dtype(cfg.dtype)
    emb = embed_tokens(params["tok"], tokens, cfg, dt)
    if cfg.family == "vlm":
        assert patches is not None
        emb = jnp.concatenate([patches.astype(dt), emb], axis=1)
    S = emb.shape[1]
    positions = jnp.arange(S)
    x, _ = _lm_trunk(cfg, params, emb, positions)
    x = rms_norm(x, params["tok"]["final_norm"], cfg.norm_eps)
    return lm_logits(params["tok"], x, cfg)


def forward_encdec(cfg, params, frames, tokens):
    dt = jnp.dtype(cfg.dtype)
    mem = frames.astype(dt)
    mem_pos = jnp.arange(mem.shape[1])
    def enc_body(x, p):
        return enc_layer_fwd(cfg, p, x, mem_pos), None
    mem, _ = _scan_layers(enc_body, mem, _cast_once(cfg, params["enc"]), remat=cfg.remat_policy)
    mem = rms_norm(mem, params["enc_final_norm"], cfg.norm_eps)

    x = embed_tokens(params["tok"], tokens, cfg, dt)
    pos = jnp.arange(x.shape[1])
    def dec_body(x, p):
        x, _ = dec_layer_fwd(cfg, p, x, mem, pos, mem_pos)
        return x, None
    x, _ = _scan_layers(dec_body, x, _cast_once(cfg, params["dec"]), remat=cfg.remat_policy)
    x = rms_norm(x, params["tok"]["final_norm"], cfg.norm_eps)
    return lm_logits(params["tok"], x, cfg)


def loss_fn(cfg, params, batch):
    """batch: dict with family-dependent inputs + labels (+optional mask)."""
    if cfg.family == "encdec":
        logits = forward_encdec(cfg, params, batch["frames"], batch["tokens"])
        return softmax_xent(logits, batch["labels"], batch.get("mask"))
    logits = forward_lm(cfg, params, batch["tokens"], batch.get("patches"))
    if cfg.family == "vlm":
        P = batch["patches"].shape[1]
        logits = logits[:, P:]
    return softmax_xent(logits, batch["labels"], batch.get("mask"))


# ---------------------------------------------------------------------------
# KV / state caches
# ---------------------------------------------------------------------------

def init_cache(cfg, batch, max_len, dtype=None):
    """Zero cache pytree for decode. Shapes match cache_specs()."""
    dt = jnp.dtype(dtype or cfg.dtype)
    Hkv, Dh = cfg.n_kv_heads, cfg.head_dim
    fam = cfg.family

    def kv(n, T):
        return (jnp.zeros((n, batch, T, Hkv, Dh), dt),
                jnp.zeros((n, batch, T, Hkv, Dh), dt))

    if fam in ("dense", "moe", "vlm"):
        k, v = kv(cfg.n_layers, max_len)
        return {"k": k, "v": v, "len": jnp.zeros((), jnp.int32)}
    if fam == "ssm":
        Din = cfg.d_inner
        return {"h": jnp.zeros((cfg.n_layers, batch, Din, cfg.d_state), jnp.float32),
                "conv": jnp.zeros((cfg.n_layers, batch, cfg.d_conv - 1, Din), dt),
                "len": jnp.zeros((), jnp.int32)}
    if fam == "hybrid":
        g_ids, _ = hybrid_split(cfg)
        nG, nW = len(g_ids), cfg.n_layers - len(g_ids)
        W = min(cfg.swa_window, max_len)
        kg, vg = kv(nG, max_len)
        kw, vw = kv(nW, W)
        Din = cfg.d_inner
        return {"kg": kg, "vg": vg, "kw": kw, "vw": vw,
                "wpos": jnp.full((nW, batch, W), -1, jnp.int32),
                "hg": jnp.zeros((nG, batch, Din, cfg.d_state), jnp.float32),
                "convg": jnp.zeros((nG, batch, cfg.d_conv - 1, Din), dt),
                "hw": jnp.zeros((nW, batch, Din, cfg.d_state), jnp.float32),
                "convw": jnp.zeros((nW, batch, cfg.d_conv - 1, Din), dt),
                "len": jnp.zeros((), jnp.int32)}
    if fam == "encdec":
        dec_len = min(max_len, 4096)
        k, v = kv(cfg.n_dec_layers, dec_len)
        ck, cv = kv(cfg.n_dec_layers, max_len)
        return {"k": k, "v": v, "ck": ck, "cv": cv,
                "enc_len": jnp.zeros((), jnp.int32),
                "len": jnp.zeros((), jnp.int32)}
    raise ValueError(fam)


def cache_specs(cfg, long_context=False):
    """PartitionSpec pytree matching init_cache. KV sequence dim is sharded
    (logical kv_seq / kv_seq_long) — decode attention lowers to a
    flash-decoding-style partial-softmax combine over that axis."""
    seq_ax = "kv_seq_long" if long_context else "kv_seq"
    kvs = ax(None, "batch", seq_ax, None, None)
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        return {"k": kvs, "v": kvs, "len": ax()}
    if fam == "ssm":
        return {"h": ax(None, "batch", "tensor", None),
                "conv": ax(None, "batch", None, "tensor"), "len": ax()}
    if fam == "hybrid":
        win = ax(None, "batch", "kv_seq", None, None)
        return {"kg": kvs, "vg": kvs, "kw": win, "vw": win,
                "wpos": ax(None, "batch", "kv_seq"),
                "hg": ax(None, "batch", "tensor", None),
                "convg": ax(None, "batch", None, "tensor"),
                "hw": ax(None, "batch", "tensor", None),
                "convw": ax(None, "batch", None, "tensor"),
                "len": ax()}
    if fam == "encdec":
        dec = ax(None, "batch", None, None, None)
        return {"k": dec, "v": dec, "ck": kvs, "cv": kvs,
                "enc_len": ax(), "len": ax()}
    raise ValueError(fam)


# ---------------------------------------------------------------------------
# Prefill
# ---------------------------------------------------------------------------

def prefill(cfg, params, batch_inputs, max_len):
    """Run full-sequence forward and populate a decode cache."""
    dt = jnp.dtype(cfg.dtype)
    fam = cfg.family
    cache = init_cache(cfg, _prefill_batchsize(cfg, batch_inputs), max_len)

    if fam == "encdec":
        frames, tokens = batch_inputs["frames"], batch_inputs["tokens"]
        mem = frames.astype(dt)
        mem_pos = jnp.arange(mem.shape[1])
        def enc_body(x, p):
            return enc_layer_fwd(cfg, p, x, mem_pos), None
        mem, _ = _scan_layers(enc_body, mem, _cast_once(cfg, params["enc"]), remat=cfg.remat_policy)
        mem = rms_norm(mem, params["enc_final_norm"], cfg.norm_eps)
        x = embed_tokens(params["tok"], tokens, cfg, dt)
        pos = jnp.arange(x.shape[1])
        def dec_body(x, p):
            x, kv = dec_layer_fwd(cfg, p, x, mem, pos, mem_pos, collect=True)
            return x, kv
        x, (k, v, ck, cv) = _scan_layers(dec_body, x, _cast_once(cfg, params["dec"]),
                                         remat=cfg.remat_policy)
        S = tokens.shape[1]
        cache["k"] = cache["k"].at[:, :, :S].set(k)
        cache["v"] = cache["v"].at[:, :, :S].set(v)
        cache["ck"] = cache["ck"].at[:, :, :ck.shape[2]].set(ck)
        cache["cv"] = cache["cv"].at[:, :, :cv.shape[2]].set(cv)
        cache["enc_len"] = jnp.asarray(ck.shape[2], jnp.int32)
        cache["len"] = jnp.asarray(S, jnp.int32)
        x = rms_norm(x, params["tok"]["final_norm"], cfg.norm_eps)
        return lm_logits(params["tok"], x[:, -1:], cfg), cache

    tokens = batch_inputs["tokens"]
    emb = embed_tokens(params["tok"], tokens, cfg, dt)
    if fam == "vlm" and batch_inputs.get("patches") is not None:
        emb = jnp.concatenate([batch_inputs["patches"].astype(dt), emb], 1)
    S = emb.shape[1]
    positions = jnp.arange(S)
    x, collected = _lm_trunk(cfg, params, emb, positions, collect=True)

    if fam in ("dense", "moe", "vlm"):
        k, v = collected
        cache["k"] = cache["k"].at[:, :, :S].set(k)
        cache["v"] = cache["v"].at[:, :, :S].set(v)
    elif fam == "ssm":
        h, conv = collected
        cache["h"], cache["conv"] = h, conv
    elif fam == "hybrid":
        caches_g, caches_w = collected
        W = cache["kw"].shape[2]
        # globals: list of ((k,v), (h, conv)) per global layer
        for i, ((k, v), (h, conv)) in enumerate(caches_g):
            cache["kg"] = cache["kg"].at[i, :, :S].set(k)
            cache["vg"] = cache["vg"].at[i, :, :S].set(v)
            cache["hg"] = cache["hg"].at[i].set(h)
            cache["convg"] = cache["convg"].at[i].set(conv)
        off = 0
        for (kv_st, (h, conv)) in caches_w:
            k, v = kv_st
            n = k.shape[0]
            pos = jnp.arange(max(0, S - W), S)
            slots = pos % W
            cache["kw"] = cache["kw"].at[off:off + n, :, slots].set(k[:, :, pos])
            cache["vw"] = cache["vw"].at[off:off + n, :, slots].set(v[:, :, pos])
            cache["wpos"] = cache["wpos"].at[off:off + n, :, slots].set(pos)
            cache["hw"] = cache["hw"].at[off:off + n].set(h)
            cache["convw"] = cache["convw"].at[off:off + n].set(conv)
            off += n
    cache["len"] = jnp.asarray(S, jnp.int32)
    x = rms_norm(x, params["tok"]["final_norm"], cfg.norm_eps)
    return lm_logits(params["tok"], x[:, -1:], cfg), cache


def _prefill_batchsize(cfg, batch_inputs):
    for k in ("tokens", "frames"):
        if k in batch_inputs:
            return batch_inputs[k].shape[0]
    raise ValueError("no batch input")


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def _cache_write(cache, val, pos):
    """Write one token into [B, T, ...] at position ``pos`` (scalar, or [B]
    for per-lane continuous batching)."""
    if pos.ndim == 0:
        return jax.lax.dynamic_update_index_in_dim(cache, val, pos, 1)
    return jax.vmap(
        lambda c, vv, pp: jax.lax.dynamic_update_index_in_dim(c, vv, pp, 0)
    )(cache, val, pos)


def _decode_attn_layer(cfg, p, x, k_cache, v_cache, pos, kv_len, *, window=0,
                       wpos=None):
    """One decode attention sublayer. ``pos`` is a scalar or a per-lane [B]
    vector (continuous batching). Returns (attn_out, k, v, wpos)."""
    positions = pos[None, None] if pos.ndim == 0 else pos[:, None]
    q, k, v = attn_qkv(p["attn"], x, cfg, positions)
    if window and wpos is not None:
        slot = pos % k_cache.shape[1]
        k_cache = _cache_write(k_cache, k[:, 0], slot)
        v_cache = _cache_write(v_cache, v[:, 0], slot)
        new_pos = jnp.broadcast_to(pos, wpos.shape[:1]).astype(jnp.int32)
        if slot.ndim == 0:
            wpos = jax.lax.dynamic_update_index_in_dim(wpos, new_pos, slot, 1)
        else:
            wpos = jax.vmap(lambda w, np_, s: jax.lax.
                            dynamic_update_index_in_dim(w, np_, s, 0)
                            )(wpos, new_pos, slot)
        bias = jnp.where(wpos >= 0, 0.0, -1e30)[:, None, None, :].astype(jnp.float32)
        kk = Lyr.repeat_kv(k_cache, cfg.n_heads // cfg.n_kv_heads)
        vv = Lyr.repeat_kv(v_cache, cfg.n_heads // cfg.n_kv_heads)
        o = Lyr._attn_core(q, kk, vv, bias)
        return o, k_cache, v_cache, wpos
    k_cache = _cache_write(k_cache, k[:, 0], pos)
    v_cache = _cache_write(v_cache, v[:, 0], pos)
    o = decode_attention(q, k_cache, v_cache, kv_len)
    return o, k_cache, v_cache, None


def decode_step(cfg, params, cache, token, patches=None):
    """One-token decode. token [B,1] int32. Returns (logits [B,1,V], cache)."""
    dt = jnp.dtype(cfg.dtype)
    fam = cfg.family
    pos = cache["len"]
    kv_len = pos + 1
    x = embed_tokens(params["tok"], token, cfg, dt)

    if fam in ("dense", "moe", "vlm"):
        # cache lives in the scan CARRY (updated via DUS at the layer index)
        # rather than streaming through xs/ys — XLA keeps ONE cache buffer
        # in place instead of double-buffering it (§Perf: ~-2x decode temp)
        def body(carry, sl):
            x, kall, vall = carry
            p, i = sl
            kc = jax.lax.dynamic_index_in_dim(kall, i, 0, keepdims=False)
            vc = jax.lax.dynamic_index_in_dim(vall, i, 0, keepdims=False)
            h = rms_norm(x, p["ln1"], cfg.norm_eps)
            o, kc, vc, _ = _decode_attn_layer(cfg, p, h, kc, vc, pos, kv_len)
            x = x + attn_out(p["attn"], o, cfg)
            h = rms_norm(x, p["ln2"], cfg.norm_eps)
            if "router" in p["mlp"]:
                x = x + Moe.moe_apply(p["mlp"], h, cfg.replace(moe_group=1))
            else:
                x = x + mlp_apply(p["mlp"], h, cfg)
            kall = jax.lax.dynamic_update_index_in_dim(kall, kc, i, 0)
            vall = jax.lax.dynamic_update_index_in_dim(vall, vc, i, 0)
            return (x, kall, vall), None
        L = _cast_once(cfg, params["layers"])["ln1"].shape[0]
        (x, k, v), _ = jax.lax.scan(
            body, (x, cache["k"], cache["v"]),
            (_cast_once(cfg, params["layers"]), jnp.arange(L, dtype=jnp.int32)))
        cache = dict(cache, k=k, v=v, len=kv_len)

    elif fam == "ssm":
        def body(x, sl):
            p, h0, c0 = sl
            hh = rms_norm(x, p["ln1"], cfg.norm_eps)
            y, (h1, c1) = M.mamba_step(p["ssm"], hh, cfg, (h0, c0))
            return x + y, (h1, c1)
        x, (h, conv) = jax.lax.scan(body, x, (_cast_once(cfg, params["layers"]), cache["h"], cache["conv"]))
        cache = dict(cache, h=h, conv=conv, len=kv_len)

    elif fam == "hybrid":
        g_ids, spans = hybrid_split(cfg)

        def hybrid_decode(p, x, kc, vc, h0, c0, *, window, wpos=None):
            hh = rms_norm(x, p["ln1"], cfg.norm_eps)
            o, kc, vc, wpos = _decode_attn_layer(cfg, p, hh, kc, vc, pos, kv_len,
                                                 window=window, wpos=wpos)
            ao = attn_out(p["attn"], o, cfg)
            so, (h1, c1) = M.mamba_step(p["ssm"], hh, cfg, (h0, c0))
            fused = 0.5 * (rms_norm(ao, p["ln_attn"], cfg.norm_eps)
                           + rms_norm(so, p["ln_ssm"], cfg.norm_eps))
            x = x + fused
            h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
            x = x + mlp_apply(p["mlp"], h2, cfg)
            return x, kc, vc, h1, c1, wpos

        def swa_body(x, sl):
            p, kc, vc, wp, h0, c0 = sl
            x, kc, vc, h1, c1, wp = hybrid_decode(p, x, kc, vc, h0, c0,
                                                  window=cfg.swa_window, wpos=wp)
            return x, (kc, vc, wp, h1, c1)

        new_g = {k: [] for k in ("kg", "vg", "hg", "convg")}
        ws_out, off = [], 0
        for gi, span in enumerate(spans):
            if span > 0:
                sl = jax.tree.map(lambda a: a[off:off + span],
                                  (_cast_once(cfg, params["swa"]), cache["kw"], cache["vw"],
                                   cache["wpos"], cache["hw"], cache["convw"]))
                x, outs = jax.lax.scan(swa_body, x, sl)
                ws_out.append(outs)
                off += span
            if gi < len(g_ids):
                pg = jax.tree.map(lambda a: a[gi], _cast_once(cfg, params["global"]))
                x, kc, vc, h1, c1, _ = hybrid_decode(
                    pg, x, cache["kg"][gi], cache["vg"][gi],
                    cache["hg"][gi], cache["convg"][gi], window=0)
                for key, val in zip(("kg", "vg", "hg", "convg"), (kc, vc, h1, c1)):
                    new_g[key].append(val)
        if ws_out:
            kw, vw, wp, hw, convw = [jnp.concatenate([o[i] for o in ws_out], 0)
                                     for i in range(5)]
        else:
            kw, vw, wp, hw, convw = (cache["kw"], cache["vw"], cache["wpos"],
                                     cache["hw"], cache["convw"])
        cache = dict(cache,
                     kg=jnp.stack(new_g["kg"]), vg=jnp.stack(new_g["vg"]),
                     hg=jnp.stack(new_g["hg"]), convg=jnp.stack(new_g["convg"]),
                     kw=kw, vw=vw, wpos=wp, hw=hw, convw=convw, len=kv_len)

    elif fam == "encdec":
        mem_len = cache["enc_len"]
        def body(carry, sl):
            x, kall, vall = carry
            p, ck, cv, i = sl
            kc = jax.lax.dynamic_index_in_dim(kall, i, 0, keepdims=False)
            vc = jax.lax.dynamic_index_in_dim(vall, i, 0, keepdims=False)
            h = rms_norm(x, p["ln1"], cfg.norm_eps)
            o, kc, vc, _ = _decode_attn_layer(cfg, p, h, kc, vc, pos, kv_len)
            x = x + attn_out(p["attn"], o, cfg)
            h = rms_norm(x, p["lnx"], cfg.norm_eps)
            qx, _, _ = attn_qkv(p["xattn"], h, cfg,
                                pos[None, None] if pos.ndim == 0
                                else pos[:, None])
            ox = decode_attention(qx, ck, cv, mem_len)
            x = x + attn_out(p["xattn"], ox, cfg)
            h = rms_norm(x, p["ln2"], cfg.norm_eps)
            x = x + mlp_apply(p["mlp"], h, cfg)
            kall = jax.lax.dynamic_update_index_in_dim(kall, kc, i, 0)
            vall = jax.lax.dynamic_update_index_in_dim(vall, vc, i, 0)
            return (x, kall, vall), None
        Ld = _cast_once(cfg, params["dec"])["ln1"].shape[0]
        (x, k, v), _ = jax.lax.scan(
            body, (x, cache["k"], cache["v"]),
            (_cast_once(cfg, params["dec"]), cache["ck"], cache["cv"],
             jnp.arange(Ld, dtype=jnp.int32)))
        cache = dict(cache, k=k, v=v, len=kv_len)
    else:
        raise ValueError(fam)

    x = rms_norm(x, params["tok"]["final_norm"], cfg.norm_eps)
    return lm_logits(params["tok"], x, cfg), cache
