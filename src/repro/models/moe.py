"""Mixture-of-Experts FFN.

Two dispatch implementations:

* ``capacity`` — GShard/Switch-style grouped capacity dispatch via one-hot
  matmuls (TPU-native: everything is a GEMM on the MXU; overcompute bounded
  by ``top_k * capacity_factor / 1``). Tokens over capacity are dropped
  (residual passes them through). Used for the production dry-runs.
* ``dense`` — computes every expert for every token and combines with router
  weights. Exact (no dropping), wasteful by E/top_k; used as the correctness
  oracle in tests and for tiny smoke configs.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import TSpec
from repro.models.sharding import constrain, weight_gather


def moe_template(cfg, stacked=None):
    D, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    L = (stacked,) if stacked else ()
    LN = (None,) if stacked else ()
    return {
        "router": TSpec(L + (D, E), LN + (None, None), 0.02),
        "w_gate": TSpec(L + (E, D, F), LN + ("expert", "fsdp", "tensor"), 0.02),
        "w_up": TSpec(L + (E, D, F), LN + ("expert", "fsdp", "tensor"), 0.02),
        "w_down": TSpec(L + (E, F, D), LN + ("expert", "tensor", "fsdp"),
                        0.02 / math.sqrt(2 * cfg.n_layers)),
    }


def _router_probs(p, x, cfg):
    logits = x.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    return jax.nn.softmax(logits, axis=-1)           # [..., E]


def _expert_ffn(p, xe, dt, cfg=None):
    """xe [..., E, C, D] per-expert token blocks -> same shape."""
    wg = weight_gather(cfg, p["w_gate"].astype(dt), ("expert", "fsdp", "tensor"))
    wu = weight_gather(cfg, p["w_up"].astype(dt), ("expert", "fsdp", "tensor"))
    wd = weight_gather(cfg, p["w_down"].astype(dt), ("expert", "tensor", "fsdp"))
    h = jnp.einsum("...ecd,edf->...ecf", xe, wg)
    u = jnp.einsum("...ecd,edf->...ecf", xe, wu)
    h = jax.nn.silu(h) * u
    h = constrain(h, "batch", None, None, "tensor")
    return jnp.einsum("...ecf,efd->...ecd", h, wd)


def moe_apply_dense(p, x, cfg):
    """Exact dense-compute MoE (oracle)."""
    dt = x.dtype
    B, S, D = x.shape
    probs = _router_probs(p, x, cfg)                 # [B,S,E]
    topv, topi = jax.lax.top_k(probs, cfg.top_k)
    gates = jnp.zeros_like(probs).at[
        jnp.arange(B)[:, None, None], jnp.arange(S)[None, :, None], topi
    ].set(topv)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    h = jnp.einsum("bsd,edf->bsef", x, p["w_gate"].astype(dt))
    u = jnp.einsum("bsd,edf->bsef", x, p["w_up"].astype(dt))
    y = jnp.einsum("bsef,efd->bsed", jax.nn.silu(h) * u, p["w_down"].astype(dt))
    return jnp.einsum("bsed,bse->bsd", y, gates.astype(dt))


def moe_apply_capacity(p, x, cfg):
    """GShard grouped capacity dispatch.

    x [B,S,D] -> group tokens into [G, g, D]; per group, each expert takes at
    most C = ceil(g * top_k / E * capacity_factor) tokens (one-hot position
    assignment via masked cumsum); dispatch/combine are einsums.
    """
    dt = x.dtype
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    g = min(cfg.moe_group, T)
    while T % g:          # largest divisor of T not exceeding moe_group
        g -= 1
    G = T // g
    C = int(math.ceil(g * K / E * cfg.capacity_factor))
    C = min(C, g)

    xt = x.reshape(G, g, D)
    probs = _router_probs(p, xt, cfg)                # [G,g,E] fp32
    topv, topi = jax.lax.top_k(probs, K)             # [G,g,K]
    denom = jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)
    topv = topv / denom

    # expert one-hots per routing slot k: [G,g,K,E]
    sel = jax.nn.one_hot(topi, E, dtype=jnp.float32)
    # priority: iterate k slots; position_in_expert via cumsum over tokens
    # flatten slot-major so slot 0 of all tokens beats slot 1 (GShard order)
    sel_sm = sel.transpose(0, 2, 1, 3).reshape(G, K * g, E)       # [G,K*g,E]
    pos = jnp.cumsum(sel_sm, axis=1) - sel_sm                      # [G,K*g,E]
    keep = (pos < C).astype(jnp.float32) * sel_sm
    pos = jnp.where(keep > 0, pos, 0.0)
    keep_t = keep.reshape(G, K, g, E).transpose(0, 2, 1, 3)        # [G,g,K,E]
    pos_t = pos.reshape(G, K, g, E).transpose(0, 2, 1, 3)

    # a token routes to each expert at most once, so reducing over the K
    # slot axis FIRST avoids materializing the 5-D [G,g,K,E,C] one-hot
    sel_e = keep_t.sum(axis=2)                                     # [G,g,E]
    pos_e = (keep_t * pos_t).sum(axis=2)                           # [G,g,E]
    gate_e = jnp.einsum("gsk,gske->gse", topv, keep_t)             # [G,g,E]
    slot_iota = jnp.arange(C, dtype=jnp.float32)
    pos_oh = (pos_e[..., None] == slot_iota) & (sel_e[..., None] > 0)
    dispatch = pos_oh.astype(dt)                                   # [G,g,E,C]
    combine = gate_e[..., None].astype(dt) * dispatch

    xe = jnp.einsum("gsec,gsd->gecd", dispatch, xt)                # [G,E,C,D]
    xe = constrain(xe, "batch", None, None, None)
    ye = _expert_ffn(p, xe, dt, cfg)                               # [G,E,C,D]
    yt = jnp.einsum("gsec,gecd->gsd", combine, ye)
    return constrain(yt.reshape(B, S, D), "batch", None, None)


def moe_apply(p, x, cfg):
    if cfg.moe_impl == "dense":
        return moe_apply_dense(p, x, cfg)
    return moe_apply_capacity(p, x, cfg)


def aux_load_balance_loss(p, x, cfg):
    """Switch-style load-balance auxiliary loss (fraction * prob per expert)."""
    probs = _router_probs(p, x, cfg).reshape(-1, cfg.n_experts)
    top1 = jnp.argmax(probs, axis=-1)
    frac = jnp.mean(jax.nn.one_hot(top1, cfg.n_experts, dtype=jnp.float32), 0)
    pmean = probs.mean(0)
    return cfg.n_experts * jnp.sum(frac * pmean)
