"""Mamba-1 selective SSM (chunked associative scan + single-token step).

Train path: sequence is split into chunks of ``cfg.ssm_chunk``; an outer
``lax.scan`` carries the SSM state across chunks while each chunk runs a
log-depth ``associative_scan`` — bounding the materialized element tensor to
[B, chunk, d_inner, d_state] (VMEM/HBM-friendly) instead of the full
sequence. Decode path: O(1) state update.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import TSpec
from repro.models.layers import res_constrain
from repro.models.sharding import constrain, weight_gather


def ssm_template(cfg, stacked=None, d_model=None):
    D = d_model or cfg.d_model
    Din = cfg.expand * D
    R = cfg.dt_rank or -(-D // 16)
    N, K = cfg.d_state, cfg.d_conv
    L = (stacked,) if stacked else ()
    LN = (None,) if stacked else ()
    return {
        "in_proj": TSpec(L + (D, 2 * Din), LN + ("fsdp", "tensor"), 0.02),
        "conv_w": TSpec(L + (K, Din), LN + (None, "tensor"), 0.02),
        "conv_b": TSpec(L + (Din,), LN + ("tensor",), 0.0),
        "x_proj": TSpec(L + (Din, R + 2 * N), LN + ("tensor", None), 0.02),
        "dt_proj": TSpec(L + (R, Din), LN + (None, "tensor"), 0.02),
        "dt_bias": TSpec(L + (Din,), LN + ("tensor",), 0.0),
        "A_log": TSpec(L + (Din, N), LN + ("tensor", None), 0.02),
        "D": TSpec(L + (Din,), LN + ("tensor",), -1.0),
        "out_proj": TSpec(L + (Din, D), LN + ("tensor", "fsdp"),
                          0.02 / math.sqrt(2 * cfg.n_layers)),
    }


def _causal_conv(x, w, b, init_state=None):
    """Depthwise causal conv. x [B,S,Din], w [K,Din]. init_state [B,K-1,Din]."""
    K = w.shape[0]
    if init_state is None:
        init_state = jnp.zeros((x.shape[0], K - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([init_state, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype)
              for i in range(K))
    new_state = xp[:, -(K - 1):] if K > 1 else init_state
    return out + b.astype(x.dtype), new_state


def _ssm_coeffs(p, xc, cfg):
    """xc [B,S,Din] post-conv. Returns (a, bx, Cc, D) with
    a [B,S,Din,N] decay, bx [B,S,Din,N] input, Cc [B,S,N]."""
    R = p["dt_proj"].shape[0]
    N = cfg.d_state
    proj = xc @ p["x_proj"].astype(xc.dtype)          # [B,S,R+2N]
    dt, Bc, Cc = jnp.split(proj, [R, R + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) @ p["dt_proj"].astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))  # [B,S,Din]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))      # [Din,N]
    a = jnp.exp(dt[..., None] * A)                    # [B,S,Din,N]
    bx = (dt * xc.astype(jnp.float32))[..., None] * Bc.astype(jnp.float32)[..., None, :]
    return a, bx, Cc.astype(jnp.float32)


def _chunk_scan(a, bx, h0):
    """Within-chunk scan. a,bx [B,C,Din,N]; h0 [B,Din,N] -> (ys_state, h_end)."""
    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br
    ca, cb = jax.lax.associative_scan(combine, (a, bx), axis=1)
    hs = ca * h0[:, None] + cb                        # [B,C,Din,N]
    return hs, hs[:, -1]


def mamba_mixer(p, x, cfg, state=None):
    """Full-sequence mixer. x [B,S,D]. Returns (y [B,S,D], (h, conv_state))."""
    dt = x.dtype
    B, S, _ = x.shape
    Din = p["in_proj"].shape[-1] // 2
    N = cfg.d_state
    w_in = weight_gather(cfg, p["in_proj"].astype(dt), ("fsdp", "tensor"))
    xz = x @ w_in
    xr, z = jnp.split(xz, 2, axis=-1)
    xr = constrain(xr, "batch", None, "tensor")
    if state is None:
        h0 = jnp.zeros((B, Din, N), jnp.float32)
        conv0 = None
    else:
        h0, conv0 = state
    xc, conv_state = _causal_conv(xr, p["conv_w"], p["conv_b"], conv0)
    xc = jax.nn.silu(xc)

    chunk = min(cfg.ssm_chunk, S) or S
    if S % chunk != 0:
        chunk = S
    nC = S // chunk

    def chunk_body(h, xc_c):
        a, bx, Cc = _ssm_coeffs(p, xc_c, cfg)
        hs, h_end = _chunk_scan(a, bx, h)
        y = jnp.einsum("bcdn,bcn->bcd", hs, Cc)       # fp32
        return h_end, y

    if nC > 1:
        xcs = xc.reshape(B, nC, chunk, Din).transpose(1, 0, 2, 3)
        h_end, ys = jax.lax.scan(chunk_body, h0, xcs)
        y = ys.transpose(1, 0, 2, 3).reshape(B, S, Din)
    else:
        h_end, y = chunk_body(h0, xc)

    y = y + p["D"].astype(jnp.float32) * xc.astype(jnp.float32)
    y = (y.astype(dt)) * jax.nn.silu(z)
    y = constrain(y, "batch", None, "tensor")
    w_out = weight_gather(cfg, p["out_proj"].astype(dt), ("tensor", "fsdp"))
    out = y @ w_out
    return res_constrain(cfg, out), (h_end, conv_state)


def mamba_step(p, x, cfg, state):
    """Single-token decode. x [B,1,D]; state (h [B,Din,N], conv [B,K-1,Din])."""
    dt = x.dtype
    B = x.shape[0]
    h, conv0 = state
    xz = x @ p["in_proj"].astype(dt)
    xr, z = jnp.split(xz, 2, axis=-1)
    xc, conv_state = _causal_conv(xr, p["conv_w"], p["conv_b"], conv0)
    xc = jax.nn.silu(xc)                              # [B,1,Din]
    a, bx, Cc = _ssm_coeffs(p, xc, cfg)
    h = a[:, 0] * h + bx[:, 0]                        # [B,Din,N]
    y = jnp.einsum("bdn,bn->bd", h, Cc[:, 0])[:, None]
    y = y + p["D"].astype(jnp.float32) * xc.astype(jnp.float32)
    y = y.astype(dt) * jax.nn.silu(z)
    out = y @ p["out_proj"].astype(dt)
    return out, (h, conv_state)


def init_mamba_state(cfg, batch, d_model=None, dtype=jnp.bfloat16):
    Din = cfg.expand * (d_model or cfg.d_model)
    h = jnp.zeros((batch, Din, cfg.d_state), jnp.float32)
    conv = jnp.zeros((batch, cfg.d_conv - 1, Din), dtype)
    return h, conv
