"""deepseek-7b [dense] — llama-arch [arXiv:2401.02954; hf].

30L d_model=4096 32H (GQA kv=32 == MHA) d_ff=11008 vocab=102400.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek_7b", family="dense",
    n_layers=30, d_model=4096, n_heads=32, n_kv_heads=32, d_head=128,
    d_ff=11008, vocab=102400,
)

def smoke_config() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                          d_head=16, d_ff=160, vocab=512, remat_policy="none")
