"""hymba-1.5b [hybrid] — parallel attn+mamba heads [arXiv:2411.13676; hf].

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16. Each layer
runs attention and an SSM head bank in parallel on the same input and
mean-fuses their (per-path RMS-normed) outputs. Sliding-window attention
(window 1024) everywhere except 3 global layers (first / middle / last) --
sub-quadratic => long_500k runs for this arch.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hymba_1p5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, d_head=64,
    d_ff=5504, vocab=32001, d_state=16, d_conv=4, expand=2,
    swa_window=1024, n_global_layers=3,
)

def smoke_config() -> ModelConfig:
    return CONFIG.replace(n_layers=6, d_model=64, n_heads=4, n_kv_heads=2,
                          d_head=16, d_ff=128, vocab=512, d_state=4,
                          swa_window=16, remat_policy="none", ssm_chunk=8)
