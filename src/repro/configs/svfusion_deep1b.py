"""SVFusion's own production configuration (the paper's architecture).

Deep1B (paper Table 2): N=1,000,000,000 vectors, D=96 (image descriptors),
fixed out-degree 32 KNN graph, pool L=64, k=10, 10,240-query batches.
MSTuring-200M (D=100) is the second config. Shapes/cells are defined in
``repro.launch.steps.SVF_SHAPES``; this module exposes them in the configs
namespace alongside the LM architectures and provides the reduced smoke
setup used by tests.

Placement on the production mesh (see core/distributed.py): the capacity
tier (vectors + graph + bitset) shards over every mesh axis — 1B x 96 fp32
= 384 GB vectors + 128 GB graph -> 2.1 GB/chip on 256 chips; each chip's
hot cache covers its shard (131,072 slots = 48 MB); queries are replicated
and per-shard top-k results merge hierarchically over the mesh axes.
"""
from repro.core.types import SearchParams

DEEP1B = dict(
    name="svfusion_deep1b",
    n=1_000_000_000, dim=96, degree=32,
    query_batch=10_240,
    cache_slots_per_chip=131_072,
    search=SearchParams(k=10, pool=64, max_iters=64),
)

MSTURING = dict(
    name="svfusion_msturing",
    n=200_000_000, dim=100, degree=32,
    query_batch=1_024,
    cache_slots_per_chip=131_072,
    search=SearchParams(k=10, pool=64, max_iters=64),
)


def smoke_config() -> dict:
    """Reduced same-family setup: used by tests/test_core.py and
    tests/test_distributed.py (small N, same algorithms end-to-end)."""
    return dict(name="svfusion_smoke", n=2_000, dim=16, degree=8,
                query_batch=32, cache_slots_per_chip=64,
                search=SearchParams(k=10, pool=48, max_iters=64))
