"""falcon-mamba-7b [ssm] — mamba1 arch [arXiv:2410.05355; unverified].

64L d_model=4096 (attention-free) vocab=65024, ssm_state=16, expand=2
(d_inner=8192), conv k=4. Sub-quadratic => long_500k runs for this arch.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="falcon_mamba_7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=0, n_kv_heads=0, d_head=0,
    d_ff=0, vocab=65024, d_state=16, d_conv=4, expand=2, tie_embeddings=True,
)

def smoke_config() -> ModelConfig:
    return CONFIG.replace(n_layers=3, d_model=64, vocab=512, d_state=4,
                          remat_policy="none", ssm_chunk=8)
