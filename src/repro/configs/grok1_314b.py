"""grok-1-314b [moe] — 8 experts top-2 [hf:xai-org/grok-1; unverified].

64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072, MoE 8e top-2.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="grok1_314b", family="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8, d_head=128,
    d_ff=32768, vocab=131072, n_experts=8, top_k=2,
)

def smoke_config() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                          d_head=16, d_ff=128, vocab=512, n_experts=4, top_k=2,
                          moe_group=64, remat_policy="none")
