"""internvl2-2b [vlm] — InternViT + InternLM2 backbone [arXiv:2404.16821; hf].

24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553. The vision frontend is
a STUB per assignment: input_specs() provides precomputed patch embeddings;
the LM backbone consumes [patches ; tokens].
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2_2b", family="vlm",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8, d_head=128,
    d_ff=8192, vocab=92553, n_patches=256, rope_theta=1e6,
)

def smoke_config() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                          d_head=16, d_ff=128, vocab=512, n_patches=8,
                          remat_policy="none")
