"""whisper-medium [audio] — enc-dec, conv frontend (stub) [arXiv:2212.04356].

24 encoder + 24 decoder layers (whisper-medium's published 24/24 stack),
d_model=1024 16H (MHA) d_ff=4096 vocab=51865. The conv frontend is a STUB:
input_specs() provides precomputed frame embeddings [B, S_enc, d_model].
Decoder: causal self-attention + cross-attention over encoder memory. For the
inference shapes, audio is the long modality: prefill_32k encodes a 32k-frame
memory then prefills the decoder; decode_32k decodes one token against a
32k-frame cross-attention memory + decoder self-KV.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper_medium", family="encdec",
    n_layers=48, n_enc_layers=24, n_dec_layers=24,
    d_model=1024, n_heads=16, n_kv_heads=16, d_head=64,
    d_ff=4096, vocab=51865,
)

def smoke_config() -> ModelConfig:
    return CONFIG.replace(n_layers=4, n_enc_layers=2, n_dec_layers=2,
                          d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
                          d_ff=128, vocab=512, remat_policy="none")
