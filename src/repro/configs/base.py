"""Model/shape configuration system.

Each assigned architecture gets one ``src/repro/configs/<id>.py`` exposing
``CONFIG`` (full published config) and ``smoke_config()`` (reduced config of
the same family for CPU tests). Shapes are global to the LM family:

    train_4k     seq_len=4096    global_batch=256   (training)
    prefill_32k  seq_len=32768   global_batch=32    (inference prefill)
    decode_32k   seq_len=32768   global_batch=128   (single-token decode w/ KV cache)
    long_500k    seq_len=524288  global_batch=1     (long-context decode; sub-quadratic archs only)
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field
from typing import Optional


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0                 # 0 -> d_model // n_heads
    qk_norm: bool = False
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_group: int = 1024           # GShard dispatch group size (tokens)
    moe_impl: str = "capacity"      # capacity | dense
    # --- SSM (Mamba-1) ---
    d_state: int = 0
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0                # 0 -> ceil(d_model / 16)
    ssm_chunk: int = 256            # chunked-scan chunk length
    # --- hybrid (Hymba-style) ---
    swa_window: int = 0             # 0 -> full attention everywhere
    n_global_layers: int = 3        # first/mid/last layers use full attention
    # --- enc-dec (Whisper-style) ---
    n_enc_layers: int = 0
    n_dec_layers: int = 0
    # --- VLM (InternVL-style): patch-embedding stub ---
    n_patches: int = 0
    # --- numerics / compile shape ---
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat_policy: str = "full"      # none | full | dots
    attn_chunk: int = 0             # 0 -> unchunked; else query-chunked attention
    # residual-stream sharding (memory lever for big train/prefill cells):
    # "seq" = Megatron-style sequence parallelism (attention archs),
    # "dmodel" = hidden-dim sharding (SSM/hybrid archs, whose seq scan
    # cannot be split), "none" = replicated residual.
    residual_shard: str = "none"
    # gather FSDP-sharded weights before the matmul (vs XLA's partial-sum +
    # output all-reduce choice). Right for token-heavy train/prefill; wrong
    # for decode where outputs are tiny. Set by launch.steps.tune_config.
    gather_weights: bool = False
    # cast the stacked layer params to the compute dtype BEFORE the layer
    # scan so FSDP all-gathers move bf16, not fp32.
    cast_params_once: bool = True
    norm_eps: float = 1e-5
    vocab_pad: int = 256

    @property
    def head_dim(self) -> int:
        if self.d_head:
            return self.d_head
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def padded_vocab(self) -> int:
        v, m = self.vocab, self.vocab_pad
        return ((v + m - 1) // m) * m

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def dt_rank_eff(self) -> int:
        return self.dt_rank or -(-self.d_model // 16)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

ARCH_IDS = [
    "internvl2_2b",
    "phi4_mini_3p8b",
    "smollm_135m",
    "deepseek_7b",
    "qwen3_0p6b",
    "hymba_1p5b",
    "grok1_314b",
    "granite_moe_1b",
    "falcon_mamba_7b",
    "whisper_medium",
]

# Sub-quadratic archs run long_500k; pure full-attention archs skip it
# (see DESIGN.md §Arch-applicability).
SUBQUADRATIC = {"hymba_1p5b", "falcon_mamba_7b"}


def shape_cells(arch: str) -> list[str]:
    """The dry-run/roofline shape cells defined for an architecture."""
    cells = ["train_4k", "prefill_32k", "decode_32k"]
    if arch in SUBQUADRATIC:
        cells.append("long_500k")
    return cells


def load_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.CONFIG


def load_smoke_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.smoke_config()
