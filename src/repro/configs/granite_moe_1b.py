"""granite-moe-1b-a400m [moe] — 32 experts top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf].

24L d_model=1024 16H (GQA kv=8) d_ff=512 vocab=49155, MoE 32e top-8.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite_moe_1b", family="moe",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8, d_head=64,
    d_ff=512, vocab=49155, n_experts=32, top_k=8, tie_embeddings=True,
)

def smoke_config() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                          d_head=16, d_ff=32, vocab=512, n_experts=8, top_k=2,
                          moe_group=64, remat_policy="none")
