"""smollm-135m [dense] — llama-arch small [hf:HuggingFaceTB/SmolLM-135M; hf].

30L d_model=576 9H (GQA kv=3) d_ff=1536 vocab=49152.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="smollm_135m", family="dense",
    n_layers=30, d_model=576, n_heads=9, n_kv_heads=3, d_head=64,
    d_ff=1536, vocab=49152, tie_embeddings=True,
)

def smoke_config() -> ModelConfig:
    return CONFIG.replace(n_layers=3, d_model=48, n_heads=3, n_kv_heads=1,
                          d_head=16, d_ff=96, vocab=512, remat_policy="none")
