"""phi4-mini-3.8b [dense] — RoPE SwiGLU GQA [arXiv:2412.08905; hf].

32L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=200064.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi4_mini_3p8b", family="dense",
    n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8, d_head=128,
    d_ff=8192, vocab=200064, rope_theta=10000.0,
)

def smoke_config() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                          d_head=16, d_ff=128, vocab=512, remat_policy="none")
