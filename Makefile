# Mechanical pass/fail bar for every PR.
#
#   make verify      — the tier-1 suite (ROADMAP.md)
#   make bench-disk  — the three-tier serving benchmark (fig. 11)
#   make bench-smoke — seconds-scale disk-backed serving bench (CI gate:
#                      catches serving-path regressions unit tests miss);
#                      runs the exact-mode AND PQ-on configs, each gated
#                      against its own config-key history
#   make bench-scale — >=10x memmap-built scale-up preset (PQ code lane,
#                      per-tier byte footprints; minutes-scale, not CI)
#   make bench-slo-smoke — open-loop hot-tenant overload storm (CI gate:
#                      shed+deadline-miss fraction < 5%, every tenant's
#                      p99 under the derived SLO target, degradation
#                      engages before any shedding, cold tenants lose
#                      nothing)
#   make bench-slo   — the full (longer) SLO storm sweep
#   make bench-filtered-smoke — filtered-search selectivity sweep (CI
#                      gate: selectivity router picks fallback below /
#                      graph lane above the threshold, recall@10 >= 0.9
#                      at 10% selectivity, filtered QPS >= 0.5x
#                      unfiltered at the 10% tag point)
#   make verify-durability — the FULL kill -9 crash matrix (every crash
#                      point x workload incl. PQ variants) + all
#                      durability unit tests; tier-1 runs only a slice

PY ?= python
export PYTHONPATH := src:$(PYTHONPATH)

.PHONY: verify test verify-durability bench-disk bench-smoke bench-scale \
        bench-slo bench-slo-smoke bench-filtered-smoke

verify:
	$(PY) -m pytest -x -q

test: verify

verify-durability:
	SVF_DURABILITY_FULL=1 $(PY) -m pytest tests/test_durability.py -q

bench-disk:
	PYTHONPATH=src:. $(PY) benchmarks/bench_disk.py

bench-smoke:
	PYTHONPATH=src:. $(PY) benchmarks/bench_disk.py --smoke --gate
	PYTHONPATH=src:. $(PY) benchmarks/bench_disk.py --smoke --gate --pq

bench-scale:
	PYTHONPATH=src:. $(PY) benchmarks/bench_disk.py --scale --gate

bench-slo-smoke:
	PYTHONPATH=src:. $(PY) benchmarks/bench_slo.py --smoke --gate

bench-slo:
	PYTHONPATH=src:. $(PY) benchmarks/bench_slo.py --gate

bench-filtered-smoke:
	PYTHONPATH=src:. $(PY) benchmarks/bench_filtered.py --gate
