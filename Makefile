# Mechanical pass/fail bar for every PR.
#
#   make verify      — the tier-1 suite (ROADMAP.md)
#   make bench-disk  — the three-tier serving benchmark (fig. 11)
#   make bench-smoke — seconds-scale disk-backed serving bench (CI gate:
#                      catches serving-path regressions unit tests miss)

PY ?= python
export PYTHONPATH := src:$(PYTHONPATH)

.PHONY: verify test bench-disk bench-smoke

verify:
	$(PY) -m pytest -x -q

test: verify

bench-disk:
	PYTHONPATH=src:. $(PY) benchmarks/bench_disk.py

bench-smoke:
	PYTHONPATH=src:. $(PY) benchmarks/bench_disk.py --smoke --gate
