"""Paper Fig. 13 + 14: insertion-phase breakdown and thread scaling."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import SVFusionAdapter, csv_row
from repro.core import update as U
from repro.core.build import build_index, compute_e_in, rank_based_reorder
from repro.core.search import _frontier_search
from repro.core.types import SearchParams


def phase_breakdown(n=5000, dim=32, batch=128, seed=0):
    """Fig 13: time insert phases separately (candidate search / heuristic
    reordering / reverse-edge add / bookkeeping)."""
    rng = np.random.default_rng(seed)
    vecs = rng.normal(size=(n, dim)).astype(np.float32)
    st = build_index(vecs, degree=16, cache_slots=512, n_max=1 << 13)
    newv = jnp.asarray(rng.normal(size=(batch, dim)), jnp.float32)
    sp = SearchParams(k=10, pool=64, max_iters=96)
    key = jax.random.PRNGKey(1)

    search_fn = jax.jit(lambda g, c, q, e: _frontier_search(
        g, c, q, e, sp._replace(k=sp.pool)))
    entries = jax.random.randint(key, (batch, sp.pool), 0,
                                 int(st.graph.n), dtype=jnp.int32)

    def timed(fn, *args):
        out = fn(*args)
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        for _ in range(3):
            out = fn(*args)
            jax.block_until_ready(out)
        return (time.perf_counter() - t0) / 3, out

    t_search, res = timed(search_fn, st.graph, st.cache, newv, entries)
    reorder_fn = jax.jit(lambda ci, cd, nb: rank_based_reorder(
        ci, cd, nb, st.graph.degree))
    t_reorder, sel = timed(reorder_fn, res.ids, res.dists, st.graph.nbrs)
    flat_t = sel.reshape(-1)
    ids = st.graph.n + jnp.arange(batch, dtype=jnp.int32)
    flat_new = jnp.repeat(ids, st.graph.degree)
    d_rev = jnp.sum((st.graph.vectors[jnp.clip(flat_t, 0)]
                     - st.graph.vectors[flat_new]) ** 2, -1)
    rev_fn = jax.jit(lambda g, t, nn, d: U._reverse_edge_scatter(g, t, nn, d))
    t_rev, _ = timed(rev_fn, st.graph, flat_t, flat_new, d_rev)
    ein_fn = jax.jit(lambda nb: compute_e_in(nb, st.graph.capacity))
    t_ein, _ = timed(ein_fn, st.graph.nbrs)

    total = t_search + t_reorder + t_rev + t_ein
    out = {"search_dist": t_search / total, "reorder": t_reorder / total,
           "reverse_add": t_rev / total, "bookkeeping": t_ein / total,
           "total_ms": total * 1e3}
    csv_row("fig13_breakdown", total / batch * 1e6, **out)
    return out


def thread_scaling(n=4000, dim=32, threads=(1, 2, 4), n_batches=12):
    """Fig 14: search throughput vs #streams (1-core container: expect
    saturation at 1, mirroring the paper's diminishing returns >16)."""
    import threading
    rng = np.random.default_rng(0)
    results = {}
    for nt in threads:
        idx = SVFusionAdapter(dim, degree=16, cache_slots=512,
                              capacity=1 << 14)
        idx.insert(rng.normal(size=(n, dim)).astype(np.float32))
        q = rng.normal(size=(32, dim)).astype(np.float32)
        idx.search(q)  # warm
        done = []

        def worker():
            for _ in range(n_batches // nt):
                idx.search(q, k=10)
                done.append(32)

        ths = [threading.Thread(target=worker) for _ in range(nt)]
        t0 = time.perf_counter()
        [t.start() for t in ths]
        [t.join() for t in ths]
        dt = time.perf_counter() - t0
        qps = sum(done) / dt
        results[nt] = qps
        csv_row(f"fig14_threads_{nt}", 1e6 / max(qps, 1e-9), qps=qps)
    return results


def main():
    return {"breakdown": phase_breakdown(), "threads": thread_scaling()}


if __name__ == "__main__":
    main()
