"""Paper Fig. 16 + 17: prediction-parameter ratio alpha/(alpha+beta) vs miss
rate, and update batch-size sweep (throughput/latency/recall trade)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import SVFusionAdapter, csv_row, exact_topk, recall
from repro.train.data import sliding_window
from benchmarks.common import run_workload


def alpha_beta_sweep(n=4000, dim=32, ratios=(0.0, 0.2, 0.4, 0.6, 0.8, 1.0)):
    """Fig 16: 0 = structure-only prediction, 1 = recency-only."""
    results = {}
    for r in ratios:
        alpha, beta = r, 1.0 - r
        idx = SVFusionAdapter(dim, degree=16, cache_slots=512,
                              capacity=1 << 15, alpha=alpha, beta=beta)
        wl = sliding_window(n=n, dim=dim, t_max=40)
        m = run_workload(idx, wl, max_steps=45, name=f"ab_{r}")
        s = m.summary()
        results[r] = s
        csv_row(f"fig16_ratio_{int(r*100)}", 1e6 / max(s["search_qps"], 1e-9),
                miss_rate=s.get("miss_rate", 0), recall=s["recall"])
    return results


def batch_size_sweep(n=4000, dim=32, batches=(8, 32, 128, 512, 2048)):
    """Fig 17: larger update batches raise throughput but delay visibility
    and stretch tail latency."""
    rng = np.random.default_rng(0)
    data = rng.normal(size=(n, dim)).astype(np.float32)
    results = {}
    for bs in batches:
        idx = SVFusionAdapter(dim, degree=16, cache_slots=512,
                              capacity=1 << 15)
        idx.insert(data[:1024])
        t0 = time.perf_counter()
        inserted = 0
        lat = []
        for s in range(1024, min(n, 1024 + 4 * bs), bs):
            t1 = time.perf_counter()
            idx.insert(data[s:s + bs])
            lat.append(time.perf_counter() - t1)
            inserted += bs
        dt = time.perf_counter() - t0
        q = data[1024:1024 + 64] + rng.normal(
            scale=0.05, size=(64, dim)).astype(np.float32)
        found = idx.search(q)
        ids_all = np.arange(1024 + inserted)
        truth = exact_topk(ids_all, data[:1024 + inserted], q, 10)
        rec = recall(found, truth)
        results[bs] = {"insert_qps": inserted / dt,
                       "p99_ms": max(lat) * 1e3, "recall": rec}
        csv_row(f"fig17_batch_{bs}", dt / max(inserted, 1) * 1e6,
                **results[bs])
    return results


def main():
    return {"alpha_beta": alpha_beta_sweep(), "batch": batch_size_sweep()}


if __name__ == "__main__":
    main()
