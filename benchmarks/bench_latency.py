"""Paper Fig. 8: p50/p95/p99 search+insert latency vs offered QPS
(open-loop arrivals via the multi-stream runner). Search requests flow
through the engine's cross-query coalescing scheduler, so higher offered
rates should show deeper merged micro-batches (``coalesce_batch_mean``)
rather than proportionally higher dispatch counts."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import SVFusionAdapter, csv_row
from repro.core.engine import MultiStreamRunner
from repro.utils import percentile


def main(n=4000, dim=32, rates=(200, 1000, 4000), duration=3.0):
    rng = np.random.default_rng(0)
    base = rng.normal(size=(n, dim)).astype(np.float32)
    results = {}
    for rate in rates:
        idx = SVFusionAdapter(dim, degree=16, cache_slots=768,
                              capacity=1 << 15)
        idx.insert(base)
        idx.search(rng.normal(size=(8, dim)).astype(np.float32))  # warm jit
        idx.search(rng.normal(size=(64, dim)).astype(np.float32))
        runner = MultiStreamRunner(idx.engine, n_search_streams=2,
                                   max_batch=64, batch_timeout=0.002)
        runner.start()
        t_end = time.perf_counter() + duration
        interval = 8.0 / rate                    # 8 queries per request
        nsub = 0
        while time.perf_counter() < t_end:
            runner.submit_search(
                rng.normal(size=(8, dim)).astype(np.float32), tag=nsub)
            if nsub % 10 == 0:
                runner.submit_insert(
                    rng.normal(size=(8, dim)).astype(np.float32))
            nsub += 1
            time.sleep(interval)
        runner.drain_and_stop()
        lats = sorted(r[2] for r in runner.results)
        ins = sorted(idx.engine.latencies["insert"])
        est = idx.engine.stats()
        s = {
            "p50_ms": percentile(lats, 50) * 1e3,
            "p95_ms": percentile(lats, 95) * 1e3,
            "p99_ms": percentile(lats, 99) * 1e3,
            "insert_p99_ms": percentile(ins, 99) * 1e3 if ins else 0.0,
            "completed": len(lats),
            "coalesce_batch_mean": est.get("coalesce_batch_mean", 1.0),
        }
        results[rate] = s
        csv_row(f"fig8_qps_{rate}", s["p50_ms"] * 1e3, **s)
    return results


if __name__ == "__main__":
    main()
