"""Benchmark aggregator: one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows."""
from __future__ import annotations

import argparse
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names to run")
    args, _ = ap.parse_known_args()

    from benchmarks import (bench_breakdown, bench_cache, bench_consistency,
                            bench_deletion, bench_disk, bench_gpu_methods,
                            bench_latency, bench_params, bench_streaming)
    benches = {
        "streaming": bench_streaming.main,      # Fig 7
        "latency": bench_latency.main,          # Fig 8
        "cache": bench_cache.main,              # Fig 9 + 10
        "disk": bench_disk.main,                # Fig 11
        "deletion": bench_deletion.main,        # Fig 12
        "breakdown": bench_breakdown.main,      # Fig 13 + 14
        "gpu_methods": bench_gpu_methods.main,  # Fig 15
        "params": bench_params.main,            # Fig 16 + 17
        "consistency": bench_consistency.main,  # Table 3
    }
    only = set(args.only.split(",")) if args.only else None
    failures = []
    for name, fn in benches.items():
        if only and name not in only:
            continue
        print(f"# === {name} ===", flush=True)
        t0 = time.time()
        try:
            fn()
        except Exception as e:
            failures.append(name)
            print(f"{name},0,ERROR={type(e).__name__}:{e}")
            traceback.print_exc()
        print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
    if failures:
        raise SystemExit(f"benchmarks failed: {failures}")


if __name__ == "__main__":
    main()
