"""Shared benchmark harness.

Adapters give every index (SVFusion + baselines) the same API; the runner
replays a streaming workload, maintaining an exact ground-truth mirror for
recall, and reports recall / search-qps / insert-qps / p-latencies /
miss-rate.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from repro.core.engine import EngineConfig, SVFusionEngine
from repro.core.types import SearchParams
from repro.utils import percentile


class SVFusionAdapter:
    name = "svfusion"

    def __init__(self, dim, degree=16, cache_slots=1024, capacity=1 << 16,
                 policy="wavp", pool=64, sync=True, seed=0, alpha=1.0,
                 beta=1.0):
        sp = SearchParams(k=10, pool=pool, max_iters=96, policy=policy)
        self.engine = SVFusionEngine(
            np.zeros((8, dim), np.float32) + np.arange(8)[:, None],
            EngineConfig(degree=degree, cache_slots=cache_slots,
                         capacity=capacity, search=sp, sync=sync, seed=seed))
        # the 8 seed rows are placeholders; mark them deleted
        self.engine.delete(np.arange(8))
        import jax.numpy as jnp
        st = self.engine.state
        self.engine._state = st._replace(cache=st.cache._replace(
            alpha=jnp.float32(alpha), beta=jnp.float32(beta)))

    def insert(self, vectors):
        return self.engine.insert(vectors)

    def delete(self, ids):
        self.engine.delete(ids)

    def search(self, queries, k=10):
        ids, _ = self.engine.search(queries)
        return ids[:, :k]

    def stats(self):
        return self.engine.stats()


@dataclass
class RunMetrics:
    name: str
    recalls: list = field(default_factory=list)
    search_lat: list = field(default_factory=list)
    insert_lat: list = field(default_factory=list)
    n_queries: int = 0
    n_inserted: int = 0
    n_deleted: int = 0
    extra: dict = field(default_factory=dict)

    def summary(self) -> dict:
        st = sum(self.search_lat) or 1e-9
        it = sum(self.insert_lat) or 1e-9
        return {
            "name": self.name,
            "recall": float(np.mean(self.recalls)) if self.recalls else 0.0,
            "search_qps": self.n_queries / st,
            "insert_qps": self.n_inserted / it,
            "search_p50_ms": percentile(self.search_lat, 50) * 1e3,
            "search_p99_ms": percentile(self.search_lat, 99) * 1e3,
            "insert_p99_ms": percentile(self.insert_lat, 99) * 1e3,
            **self.extra,
        }


def exact_topk(mirror_ids, mirror_vecs, queries, k):
    if len(mirror_ids) == 0:
        return np.full((len(queries), k), -2, np.int64)
    d = ((queries[:, None, :] - mirror_vecs[None, :, :]) ** 2).sum(-1)
    order = np.argsort(d, axis=1)[:, :k]
    return mirror_ids[order]


def recall(found, truth):
    hits = (found[:, :, None] == truth[:, None, :]).any(1)
    return float(hits.mean())


def run_workload(index, workload, k=10, name=None, max_steps=None) -> RunMetrics:
    m = RunMetrics(name or getattr(index, "name", type(index).__name__))
    id2vec: dict[int, np.ndarray] = {}
    for step_no, op in enumerate(workload):
        if max_steps and step_no >= max_steps:
            break
        if op.kind == "insert":
            t0 = time.perf_counter()
            ids = index.insert(op.vectors)
            m.insert_lat.append(time.perf_counter() - t0)
            m.n_inserted += len(ids)
            for i, v in zip(ids, op.vectors):
                id2vec[int(i)] = v
        elif op.kind == "delete":
            ids = np.asarray(op.ids).ravel()
            index.delete(ids)
            m.n_deleted += len(ids)
            for i in ids:
                id2vec.pop(int(i), None)
        else:
            t0 = time.perf_counter()
            found = index.search(op.queries, k=k)
            m.search_lat.append(time.perf_counter() - t0)
            m.n_queries += len(op.queries)
            mid = np.fromiter(id2vec.keys(), np.int64, len(id2vec))
            mv = np.stack([id2vec[int(i)] for i in mid]) if len(mid) else \
                np.zeros((0, op.queries.shape[1]), np.float32)
            truth = exact_topk(mid, mv, op.queries, k)
            m.recalls.append(recall(found, truth))
    if hasattr(index, "stats"):
        s = index.stats()
        m.extra["miss_rate"] = s.get("miss_rate", 0.0)
        m.extra["modeled_us"] = s.get("modeled_us_per_access", 0.0)
    if hasattr(index, "rebuilds"):
        m.extra["rebuilds"] = index.rebuilds
    return m


def csv_row(name, us_per_call, **derived):
    kv = ",".join(f"{k}={v:.4g}" if isinstance(v, float) else f"{k}={v}"
                  for k, v in derived.items())
    print(f"{name},{us_per_call:.1f},{kv}", flush=True)
