"""Filtered-search recall/QPS sweep over predicate selectivity.

Serves the PQ-on smoke config through ``engine.search(filter=...)``
across numeric-range selectivities {0.1%, 1%, 10%, 50%} plus a 10% tag
filter, measuring per-point recall@10 against an exact host-side
post-filtered scan, QPS, and the selectivity router's chosen path
(graph lane vs brute-force fallback), alongside an unfiltered baseline.
``filter_fallback_selectivity`` is pinned at 0.15 so the sub-15% points
exercise the fallback (one ADC scan over the matching id set + exact
re-rank) and the 50% point exercises the predicate-composited graph
lane — the two lanes of the tentpole, both on the gate.

Every run appends a machine-readable entry to
``results/pod256/bench_filtered.json`` (same rotation/ key machinery as
bench_disk.py; filter fields ride the config key so sweep history only
gates against itself). ``--gate`` additionally enforces the acceptance
bars: recall@10 >= 0.9 at 10% selectivity, fallback engaged below the
threshold, graph lane at 50%, and filtered QPS at the 10% tag point
>= 0.5x the unfiltered baseline.
"""
from __future__ import annotations

import argparse
import os
import sys
import tempfile
import time

import numpy as np

from benchmarks.bench_disk import RESULTS_DIR, _append_result, config_key
from repro.core.engine import EngineConfig, SVFusionEngine
from repro.core.filters import FilterSpec, AttributeSchema
from repro.core.types import SearchParams

FALLBACK_THRESHOLD = 0.15
RANGE_POINTS = (0.001, 0.01, 0.1, 0.5)     # score in [0, s) -> selectivity s
TAG_DOMAIN = 10                            # cat = i % 10 -> 10% per tag


def _exact_filtered_topk(vecs, queries, mask, k):
    """Ground truth: exact top-k over the ids passing ``mask``."""
    idx = np.where(mask)[0]
    out = np.full((len(queries), k), -1, np.int64)
    if not idx.size:
        return out
    d = ((vecs[idx][None] - queries[:, None]) ** 2).sum(-1)
    order = np.argsort(d, axis=1)[:, :k]
    top = idx[order]
    out[:, :top.shape[1]] = top
    return out


def _recall(found, truth):
    """recall@k against a truth set that may hold fewer than k ids."""
    per_q = []
    for f, t in zip(found, truth):
        ts = set(int(i) for i in t if i >= 0)
        if not ts:
            continue
        fs = set(int(i) for i in f if i >= 0)
        per_q.append(len(fs & ts) / len(ts))
    return float(np.mean(per_q)) if per_q else 1.0


def _timed_qps(eng, queries, spec, *, warmup=2, batches=8):
    for _ in range(warmup):
        eng.search(queries, filter=spec, update_cache=False)
    t0 = time.perf_counter()
    for _ in range(batches):
        eng.search(queries, filter=spec, update_cache=False)
    return batches * len(queries) / max(time.perf_counter() - t0, 1e-9)


def main(n=1200, dim=16, seed=0, *, smoke=True, gate=False,
         query_batch=32):
    rng = np.random.default_rng(seed)
    vecs = rng.normal(size=(n, dim)).astype(np.float32)
    queries = rng.normal(size=(query_batch, dim)).astype(np.float32)
    scores = (np.arange(n) / n).astype(np.float32)
    cats = np.arange(n) % TAG_DOMAIN
    schema = AttributeSchema(tag_fields=("cat",), num_fields=("score",),
                             tag_domain=TAG_DOMAIN)
    sp = SearchParams(k=10, pool=64, max_iters=96)
    meta = {"n": n, "dim": dim, "seed": seed, "smoke": smoke, "pq": True,
            "scale": False, "window_frac": 4, "filter": "sweep",
            "filter_sel": "0.001-0.5",
            "fallback_threshold": FALLBACK_THRESHOLD}

    cases = [("range", s, FilterSpec(ranges={"score": (None, s)}),
              scores < s) for s in RANGE_POINTS]
    cases.append(("tag", 1.0 / TAG_DOMAIN, FilterSpec(tags={"cat": {0}}),
                  cats == 0))

    points = []
    with tempfile.TemporaryDirectory() as td:
        eng = SVFusionEngine(vecs, EngineConfig(
            degree=16, cache_slots=512, capacity=2 * n,
            disk_path=td, disk_capacity=2 * n, host_window=n // 4,
            search=sp, seed=seed, coalesce=False, pq_enabled=True,
            pq_m=dim // 2, rerank_depth=32, attributes=schema,
            filter_fallback_selectivity=FALLBACK_THRESHOLD),
            init_attrs={"cat": cats, "score": scores})
        try:
            unfiltered_qps = _timed_qps(eng, queries, None)
            ufound, _ = eng.search(queries, update_cache=False)
            truth = _exact_filtered_topk(vecs, queries,
                                         np.ones(n, bool), 10)
            unfiltered_recall = _recall(np.asarray(ufound)[:, :10], truth)
            for kind, sel, spec, mask in cases:
                found, _ = eng.search(queries, filter=spec,
                                      update_cache=False)
                st = eng.stats()
                truth = _exact_filtered_topk(vecs, queries, mask, 10)
                points.append({
                    "kind": kind, "selectivity": sel,
                    "matches": int(mask.sum()),
                    "recall": _recall(np.asarray(found)[:, :10], truth),
                    "qps": _timed_qps(eng, queries, spec),
                    "path": st["filter_last_path"],
                    "measured_selectivity": st["filter_last_selectivity"],
                })
        finally:
            eng.close()

    results = {"meta": dict(meta,
                            timestamp=time.strftime("%Y-%m-%dT%H:%M:%S")),
               "unfiltered": {"search_qps": unfiltered_qps,
                              "recall": unfiltered_recall},
               "filtered": points}
    path = _append_result(
        results, path=os.path.join(RESULTS_DIR, "bench_filtered.json"))
    print(f"bench_filtered: appended run entry to {path} "
          f"(key {config_key(results['meta'])})", flush=True)
    for p in points:
        print(f"  {p['kind']:>5} sel={p['selectivity']:<6} "
              f"matches={p['matches']:<4} path={p['path']:<8} "
              f"recall@10={p['recall']:.3f} qps={p['qps']:.0f}", flush=True)
    print(f"  unfiltered: recall@10={unfiltered_recall:.3f} "
          f"qps={unfiltered_qps:.0f}", flush=True)

    fails = []
    for p in points:
        want = ("fallback" if p["selectivity"] < FALLBACK_THRESHOLD
                else "graph")
        if p["path"] != want:
            fails.append(f"{p['kind']} sel={p['selectivity']}: router "
                         f"chose {p['path']}, expected {want}")
    ten_pct = [p for p in points if p["selectivity"] == 0.1
               or p["kind"] == "tag"]
    for p in ten_pct:
        if p["recall"] < 0.9:
            fails.append(f"{p['kind']} sel={p['selectivity']}: recall@10 "
                         f"{p['recall']:.3f} < 0.9")
    tag = next(p for p in points if p["kind"] == "tag")
    if tag["qps"] < 0.5 * unfiltered_qps:
        fails.append(f"filtered QPS at 10% tag selectivity "
                     f"{tag['qps']:.0f} < 0.5x unfiltered "
                     f"{unfiltered_qps:.0f}")
    if fails:
        for f in fails:
            print(f"bench_filtered {'gate FAIL' if gate else 'WARN'}: {f}",
                  file=sys.stderr)
        if gate:
            raise SystemExit(1)
    elif gate:
        print("bench_filtered gate: pass (router paths, recall@10 >= 0.9 "
              "at 10% selectivity, filtered QPS >= 0.5x unfiltered)")
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale CI variant (default config IS the "
                         "smoke config; flag kept for CLI symmetry)")
    ap.add_argument("--gate", action="store_true",
                    help="fail on router misroutes, recall@10 < 0.9 at "
                         "10%% selectivity, or filtered QPS < 0.5x "
                         "unfiltered")
    ap.add_argument("--n", type=int, default=1200)
    ap.add_argument("--dim", type=int, default=16)
    args = ap.parse_args()
    main(n=args.n, dim=args.dim, gate=args.gate)
