"""Paper Table 3: read-after-write consistency under interleaved 50/50
insert+search batches — Recall@1 of the just-inserted vector, w/ and w/o
the synchronization protocol."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import csv_row
from repro.core.engine import EngineConfig, SVFusionEngine
from repro.core.types import SearchParams
from repro.utils import percentile


def run(sync: bool, n_rounds=40, batch=10, dim=32, seed=0):
    rng = np.random.default_rng(seed)
    base = rng.normal(size=(1024, dim)).astype(np.float32)
    eng = SVFusionEngine(base, EngineConfig(
        degree=16, cache_slots=512, capacity=1 << 14,
        search=SearchParams(k=1, pool=48, max_iters=64),
        sync=sync, stale_refresh=8))
    eng.search(base[:16])  # warm
    hits, lats = [], []
    for _ in range(n_rounds):
        newv = rng.normal(size=(batch, dim)).astype(np.float32)
        ids = eng.insert(newv)
        t0 = time.perf_counter()
        found, _ = eng.search(newv)          # should return the new vectors
        lats.append(time.perf_counter() - t0)
        hits.append(float((found[:, 0] == ids).mean()))
    return {"recall_at_1": float(np.mean(hits)),
            "p99_ms": percentile(lats, 99) * 1e3}


def main():
    results = {}
    for sync in (True, False):
        r = run(sync)
        results[sync] = r
        csv_row(f"table3_{'sync' if sync else 'nosync'}",
                r["p99_ms"] * 1e3, **r)
    return results


if __name__ == "__main__":
    main()
