"""SLO serving-tier benchmark: open-loop arrival-rate sweep with a
hot-tenant storm (paper §4.4 adaptive resource management).

Three stages against one disk-backed engine:

1. **Calibrate** — closed-loop clients measure the sustainable request
   rate and baseline latency at full search quality; the SLO target is
   then set relative to that baseline (a wall-clock target would gate
   the box, not the code — this machine's absolute latency is bimodal
   across runs).
2. **No-storm baseline** — light open-loop traffic over the cold
   tenants alone records the p99 each cold tenant sees when nobody is
   storming.
3. **Storm** — open-loop arrivals at ``overload`` x the sustainable
   rate with one hot tenant offered ``hot_factor`` x each cold tenant's
   rate. Every request carries a deadline. The gate (``--gate``):
   shed+deadline-miss fraction < 5%, every tenant's completed-request
   p99 under the configured target, and — if anything was shed at all —
   degraded dispatches strictly precede it (quality bends before
   requests break). Full (non-smoke) runs additionally gate cold
   tenants' storm p99 within 2x their no-storm baseline.

Entries append to ``results/pod256/bench_slo.json`` under the shared
config-key + rotation scheme (``bench_disk._append_result``).
"""
import argparse
import os
import sys
import tempfile
import threading
import time

import numpy as np

from benchmarks.bench_disk import RESULTS_DIR, _append_result, config_key
from repro.core import slo
from repro.core.engine import EngineConfig, SVFusionEngine
from repro.core.types import SearchParams
from repro.utils import percentile

BATCH = 4           # query rows per request
COLD_TENANTS = ("cold0", "cold1", "cold2")
HOT = "hot"


def _build_engine(n, dim, seed, tmp):
    rng = np.random.default_rng(seed)
    vecs = rng.normal(size=(n, dim)).astype(np.float32)
    # max_batch caps the merged dispatch at 8 row-count shapes (the
    # executor compiles per query-batch size): every shape x degrade
    # level is pre-warmed below, so the storm measures scheduling, not
    # XLA compiles. The cap is 2x what the closed-loop calibration
    # clients can keep in flight — overload headroom comes from the
    # storm coalescing DEEPER than calibration ever did, on top of the
    # degradation ladder
    eng = SVFusionEngine(vecs, EngineConfig(
        degree=8, cache_slots=256, capacity=2 * n,
        disk_path=os.path.join(tmp, "idx"), disk_capacity=2 * n,
        host_window=n // 4, coalesce_max_batch=8 * BATCH,
        search=SearchParams(k=10, pool=64, max_iters=96),
        seed=seed, slo_target_p99=0.0))   # calibrate with the tier passive
    return vecs, eng


def _closed_loop(eng, vecs, *, threads=4, duration=2.0):
    """Sustainable request rate + latency profile at full quality."""
    stop_at = time.perf_counter() + duration
    lats, lock = [], threading.Lock()

    def worker(wid):
        r = np.random.default_rng(1000 + wid)
        while time.perf_counter() < stop_at:
            sel = int(r.integers(0, len(vecs) - BATCH))
            t0 = time.perf_counter()
            eng.search(vecs[sel:sel + BATCH])
            dt = time.perf_counter() - t0
            with lock:
                lats.append(dt)

    ths = [threading.Thread(target=worker, args=(i,)) for i in range(threads)]
    t0 = time.perf_counter()
    for t in ths:
        t.start()
    for t in ths:
        t.join()
    elapsed = time.perf_counter() - t0
    return len(lats) / elapsed, lats


def _open_loop(eng, vecs, rates, duration, deadline, drain_timeout=30.0):
    """Open-loop arrivals: each tenant submits on its own clock at
    ``rates[tenant]`` req/s regardless of completions (the arrival
    process must not throttle itself on queueing — that is the whole
    point of open loop). Returns per-tenant outcome lists."""
    futs = {t: [] for t in rates}
    stop_at = time.perf_counter() + duration

    def submitter(tenant, rate):
        interval = 1.0 / rate
        r = np.random.default_rng(abs(hash(tenant)) % (2 ** 31))
        nxt = time.perf_counter()
        while True:
            now = time.perf_counter()
            if now >= stop_at:
                return
            if now < nxt:
                time.sleep(min(nxt - now, 1e-3))
                continue
            nxt += interval
            sel = int(r.integers(0, len(vecs) - BATCH))
            try:
                f = eng.submit_search(vecs[sel:sel + BATCH], tenant=tenant,
                                      deadline=deadline)
            except RuntimeError:     # engine closing under us
                return
            futs[tenant].append(f)

    ths = [threading.Thread(target=submitter, args=(t, r))
           for t, r in rates.items()]
    for t in ths:
        t.start()
    for t in ths:
        t.join()

    out = {}
    for tenant, fl in futs.items():
        lats, shed, missed, errs = [], 0, 0, 0
        for f in fl:
            try:
                f.result(timeout=drain_timeout)
                lats.append(f.latency)
            except slo.LoadShedError:
                shed += 1
            except slo.DeadlineMissError:
                missed += 1
            except Exception:        # pragma: no cover - surfaced in entry
                errs += 1
        out[tenant] = {
            "submitted": len(fl), "completed": len(lats), "shed": shed,
            "deadline_misses": missed, "errors": errs,
            "p50_ms": percentile(lats, 50) * 1e3 if lats else None,
            "p99_ms": percentile(lats, 99) * 1e3 if lats else None,
        }
    return out


def _run_once(n, dim, seed, smoke, overload, hot_factor, duration):
    calib_s = 2.0 if smoke else 4.0
    meta = {"n": n, "dim": dim, "seed": seed, "smoke": smoke,
            "pq": False, "scale": False, "window_frac": 4,
            "overload": overload, "hot_factor": hot_factor}
    with tempfile.TemporaryDirectory() as tmp:
        vecs, eng = _build_engine(n, dim, seed, tmp)
        try:
            # pre-warm every (merged-batch-size x degradation-level)
            # executor shape: a mid-storm XLA compile would be
            # attributed to queueing and poison the latency model.
            # Level 1 (re-rank halving) shares level 0's shapes in
            # exact mode, so only levels 0/2/3 compile anything new.
            for lvl in (0, 2, 3):
                for rows in range(BATCH, 8 * BATCH + 1, BATCH):
                    eng._search_exec(vecs[:rows], update_cache=False,
                                     degrade=lvl)
            _closed_loop(eng, vecs, duration=1.0)    # throwaway warm round

            sustainable, calib_lats = _closed_loop(eng, vecs,
                                                   duration=calib_s)
            base_p99 = percentile(calib_lats, 99)
            # full runs last long enough to span this box's bimodal
            # latency phases while the calibration window usually sits
            # inside ONE of them — give the derived target the extra
            # room the calibration cannot see
            target = max((5.0 if smoke else 7.0) * base_p99, 0.02)
            deadline = 3.0 * target

            cold_rate = max(overload * sustainable
                            / (len(COLD_TENANTS) + hot_factor), 1.0)

            # SLO policy live for baseline AND storm: the baseline is
            # "same system, same cold traffic, hot tenant absent"
            # shed_at=0.45: an admitted request may queue (modeled) up
            # to ~half the target before dispatch, leaving the rest for
            # service — that is what keeps even the storming tenant's
            # COMPLETED p99 under the target, not just the
            # well-behaved tenants'
            eng._coalescer.tier.set_policy(slo.SLOPolicy(
                target_p99=target, degrade_at=0.2, shed_at=0.45,
                tenant_weights={HOT: 1.0},
                default_weight=1.0))

            baseline = _open_loop(eng, vecs,
                                  {t: cold_rate for t in COLD_TENANTS},
                                  duration * 0.6, deadline)
            d0 = eng.stats()["degraded_dispatches"]

            rates = {t: cold_rate for t in COLD_TENANTS}
            rates[HOT] = hot_factor * cold_rate
            storm = _open_loop(eng, vecs, rates, duration, deadline)

            st = eng.stats()
            degraded = st["degraded_dispatches"] - d0
            tier = st["slo"]
        finally:
            eng.close()

    submitted = sum(v["submitted"] for v in storm.values())
    dropped = sum(v["shed"] + v["deadline_misses"] for v in storm.values())
    shed_frac = dropped / max(submitted, 1)
    cold_ratio = None
    ratios = [storm[t]["p99_ms"] / baseline[t]["p99_ms"]
              for t in COLD_TENANTS
              if storm[t]["p99_ms"] and baseline[t]["p99_ms"]]
    if ratios:
        cold_ratio = max(ratios)

    entry = {
        "meta": dict(meta, timestamp=time.strftime("%Y-%m-%dT%H:%M:%S")),
        "sustainable_qps": sustainable,
        "offered_qps": overload * sustainable,
        "target_p99_ms": target * 1e3,
        "calib_p99_ms": base_p99 * 1e3,
        "baseline": baseline,
        "storm": storm,
        "degraded_dispatches": degraded,
        "shed_frac": shed_frac,
        "cold_p99_ratio": cold_ratio,
        "tier": {k: tier[k] for k in ("pressure", "degrade_level",
                                      "shed", "deadline_misses",
                                      "overshoot_avoided")},
    }

    # the hard < 5% bound is the CI smoke gate; the full run offers a
    # sustained 2.1x for much longer, where the steady-state excess
    # over max-degraded capacity is the hot tenant's to absorb — bound
    # it loosely so a real shedding regression still fails
    fails = []
    shed_bound = 0.05 if smoke else 0.20
    if shed_frac >= shed_bound:
        fails.append(f"shed+miss fraction {shed_frac:.3f} >= "
                     f"{shed_bound:.0%}")
    for t, s in storm.items():
        if s["p99_ms"] is not None and s["p99_ms"] > target * 1e3:
            fails.append(f"tenant {t!r} p99 {s['p99_ms']:.1f} ms over "
                         f"target {target * 1e3:.1f} ms")
        if s["errors"]:
            fails.append(f"tenant {t!r} hit {s['errors']} hard errors")
    if dropped > 0 and degraded == 0:
        fails.append("requests were shed with zero degraded "
                     "dispatches: degradation must engage first")
    for t in COLD_TENANTS:
        # the storm must shed/starve only its author: cold tenants
        # lose nothing, and their p99 stays within 2x the no-storm
        # baseline — or, when the near-idle baseline makes that band
        # tighter than the SLO itself, keeps >=15% headroom under
        # the target (a cold tenant sees ~100 requests a run, so its
        # p99 is nearly its max — leave room for one slow dispatch)
        if storm[t]["shed"] or storm[t]["deadline_misses"]:
            fails.append(f"cold tenant {t!r} lost requests to the "
                         f"storm (shed {storm[t]['shed']}, missed "
                         f"{storm[t]['deadline_misses']})")
        p99, b99 = storm[t]["p99_ms"], baseline[t]["p99_ms"]
        if (p99 is not None and b99 is not None
                and p99 > 2.0 * b99 and p99 > 0.85 * target * 1e3):
            fails.append(f"cold tenant {t!r} storm p99 {p99:.1f} ms "
                         f"> 2x baseline {b99:.1f} ms with < 15% "
                         f"headroom under the target")

    path = _append_result(entry, path=os.path.join(RESULTS_DIR,
                                                   "bench_slo.json"))
    print(f"bench_slo: appended run entry to {path} "
          f"(key {config_key(entry['meta'])})", flush=True)
    print(f"  sustainable {sustainable:.0f} req/s, offered "
          f"{overload * sustainable:.0f} req/s (hot x{hot_factor:.0f}), "
          f"target p99 {target * 1e3:.1f} ms", flush=True)
    for t in (HOT,) + COLD_TENANTS:
        s = storm[t]
        print(f"  {t:6s} submitted {s['submitted']:5d} completed "
              f"{s['completed']:5d} shed {s['shed']:4d} missed "
              f"{s['deadline_misses']:4d} p99 "
              f"{s['p99_ms'] if s['p99_ms'] is not None else float('nan'):8.1f} ms",
              flush=True)
    print(f"  shed_frac {shed_frac:.3f}, degraded_dispatches {degraded}, "
          f"cold_p99_ratio {cold_ratio}", flush=True)
    return entry, fails


def main(n=4000, dim=32, seed=0, *, smoke=False, gate=False, overload=2.1,
         hot_factor=8.0, duration=None, attempts=3):
    """Run the storm; under ``--gate``, a violating run is re-sampled
    (fresh engine, fresh calibration) up to ``attempts`` times before
    the gate fails — this box's latency is bimodal across multi-second
    phases (see ROADMAP), so a calibration phase mismatching the storm
    phase is noise, while a genuine regression fails every attempt
    (same convention as bench_disk's median-of-3 QPS resample)."""
    duration = duration or (3.0 if smoke else 8.0)
    entry, fails = None, []
    for attempt in range(attempts if gate else 1):
        entry, fails = _run_once(n, dim, seed + attempt, smoke,
                                 overload, hot_factor, duration)
        if not fails:
            break
        if gate and attempt < attempts - 1:
            print(f"bench_slo: attempt {attempt + 1} violated the gate "
                  f"({len(fails)} check(s)); re-sampling", flush=True)
    if gate:
        for f in fails:
            print(f"bench_slo gate FAIL: {f}", file=sys.stderr)
        if fails:
            raise SystemExit(1)
        print("bench_slo gate: pass", flush=True)
    return entry


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale CI preset")
    ap.add_argument("--gate", action="store_true",
                    help="fail on SLO violations (shed>=5%%, p99>target)")
    ap.add_argument("--n", type=int, default=None)
    ap.add_argument("--overload", type=float, default=2.1,
                    help="offered rate as a multiple of sustainable")
    ap.add_argument("--hot-factor", type=float, default=8.0)
    ap.add_argument("--duration", type=float, default=None)
    a = ap.parse_args()
    n = a.n or (2500 if a.smoke else 4000)
    main(n=n, smoke=a.smoke, gate=a.gate, overload=a.overload,
         hot_factor=a.hot_factor, duration=a.duration)
