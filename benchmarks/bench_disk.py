"""Paper Fig. 11: GPU-CPU-disk three-tier framework.

(a)/(b): partitioned build (bounded memory window) vs monolithic, and its
search quality. (c): the flagship larger-than-memory serving workload —
an end-to-end streaming search+insert run through ``SVFusionEngine`` with
a disk-backed capacity tier whose host window holds only 1/4 of the
dataset, reporting QPS, per-query latency percentiles, executor
rounds/dispatches per query, recall@10 and per-tier hit/miss rates.

Every run appends a machine-readable entry to
``results/pod256/bench_disk.json`` so the bench trajectory is trackable
across PRs. ``--smoke`` runs a seconds-scale variant for CI.
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
import time

import jax
import numpy as np

from benchmarks.common import csv_row, exact_topk, recall
from repro.core.build import build_graph, build_index
from repro.core.engine import EngineConfig, SVFusionEngine
from repro.core.search import brute_force_topk, recall_at_k, search_batch
from repro.core.types import SearchParams
from repro.utils import percentile

RESULTS_DIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "results", "pod256")


def _append_result(entry: dict, path=None):
    """Append one run entry to the pod256 trajectory file (JSON list)."""
    path = path or os.path.join(RESULTS_DIR, "bench_disk.json")
    os.makedirs(os.path.dirname(path), exist_ok=True)
    hist = []
    if os.path.exists(path):
        try:
            with open(path) as f:
                hist = json.load(f)
        except (json.JSONDecodeError, OSError):
            hist = []
    hist.append(entry)
    with open(path, "w") as f:
        json.dump(hist, f, indent=2, sort_keys=True)
    return path


def _build_benchmarks(vecs, queries, sp, results, seed):
    # (a) construction: monolithic vs partitioned (bounded-window merge)
    t0 = time.perf_counter()
    g1 = build_graph(vecs, 16, n_partitions=1)
    jax.block_until_ready(g1.nbrs)
    t_mono = time.perf_counter() - t0
    t0 = time.perf_counter()
    g4 = build_graph(vecs, 16, n_partitions=4, cross_samples=256)
    jax.block_until_ready(g4.nbrs)
    t_part = time.perf_counter() - t0
    csv_row("fig11_build_monolithic", t_mono * 1e6, seconds=t_mono)
    csv_row("fig11_build_partitioned4", t_part * 1e6, seconds=t_part)
    results["build"] = {"monolithic_s": t_mono, "partitioned_s": t_part}

    # (b) search quality of the partitioned build
    st = build_index(vecs, degree=16, cache_slots=512, n_max=1 << 13,
                     n_partitions=4, cross_samples=256)
    res = search_batch(st, queries, jax.random.PRNGKey(1), sp)
    truth, _ = brute_force_topk(st.graph, queries, 10)
    rec = float(recall_at_k(res.ids, truth))
    csv_row("fig11_partitioned_recall", 0.0, recall=rec)
    results["partitioned_recall"] = rec


def _streaming_tiered(vecs, sp, results, seed, rounds=6, insert_chunk=128,
                      query_batch=64):
    """(c) end-to-end three-tier serving: dataset ≥4x the host window."""
    rng = np.random.default_rng(seed + 1)
    n, dim = vecs.shape
    n_seed = n // 2                       # half preloaded, rest streamed in
    n_final = n_seed + rounds * insert_chunk
    window = n_final // 4                 # dataset is >=4x the host window
    with tempfile.TemporaryDirectory() as td:
        eng = SVFusionEngine(vecs[:n_seed], EngineConfig(
            degree=16, cache_slots=512, capacity=2 * n,
            disk_path=td, disk_capacity=2 * n, host_window=window,
            search=sp, seed=seed))
        try:
            # cold-start warmup (paper §4.4): compile the executor's
            # dispatch pipeline at serving shape before the timed loop so
            # QPS reflects steady-state serving, not one-time jit compile
            t0 = time.perf_counter()
            for _ in range(2):
                eng.search(rng.normal(size=(query_batch, dim))
                           .astype(np.float32))
            cold_start_s = time.perf_counter() - t0
            mirror_ids = list(range(n_seed))
            recs, s_lat, i_lat = [], [], []
            n_q = n_i = 0
            cursor = n_seed
            for _ in range(rounds):
                part = vecs[cursor:cursor + insert_chunk]
                if len(part):
                    t0 = time.perf_counter()
                    ids = eng.insert(part)
                    i_lat.append(time.perf_counter() - t0)
                    n_i += len(ids)
                    mirror_ids.extend(int(i) for i in ids)
                    cursor += len(part)
                q = rng.normal(size=(query_batch, dim)).astype(np.float32)
                t0 = time.perf_counter()
                found, _ = eng.search(q)
                s_lat.append(time.perf_counter() - t0)
                n_q += len(q)
                mid = np.asarray(mirror_ids, np.int64)
                truth = exact_topk(mid, vecs[:cursor], q, 10)
                recs.append(recall(found[:, :10], truth))
            st = eng.stats()
            # per-query latency: batches share one dispatch pipeline, so
            # the per-query figure is batch latency / batch size
            pq_ms = [lat / query_batch * 1e3 for lat in s_lat]
            out = {
                "recall": float(np.mean(recs)),
                "search_qps": n_q / max(sum(s_lat), 1e-9),
                "insert_qps": n_i / max(sum(i_lat), 1e-9),
                "search_p50_ms_per_query": percentile(pq_ms, 50),
                "search_p95_ms_per_query": percentile(pq_ms, 95),
                "search_p99_ms_per_query": percentile(pq_ms, 99),
                "rounds_per_query": st["search_rounds_per_batch"],
                "dispatches_per_query": st["search_dispatches_per_batch"],
                "cold_start_s": cold_start_s,
                "beam": sp.beam,
                "hop_budget": sp.max_iters,
                "device_miss_rate": st["miss_rate"],
                "host_miss_rate": st["host_miss_rate"],
                "device_hits": st["hits"],
                "host_hits": st["host_hits"],
                "disk_reads": st["disk_reads"],
                "prefetched": st["prefetched"],
                "window_over_dataset": window / cursor,
            }
            assert cursor >= 4 * window    # larger-than-window guarantee
            csv_row("fig11_tiered_serving", 0.0, **out)
            results["tiered_serving"] = out
        finally:
            eng.close()


def main(n=6000, dim=32, seed=0, *, smoke=False, recall_bar=0.8):
    rng = np.random.default_rng(seed)
    vecs = rng.normal(size=(n, dim)).astype(np.float32)
    queries = rng.normal(size=(64, dim)).astype(np.float32)
    sp = SearchParams(k=10, pool=64, max_iters=96)
    results = {}
    if not smoke:   # build comparison is minutes-scale; skip in CI smoke
        _build_benchmarks(vecs, queries, sp, results, seed)
    _streaming_tiered(vecs, sp, results, seed,
                      rounds=2 if smoke else 6,
                      insert_chunk=64 if smoke else 128,
                      query_batch=32 if smoke else 64)
    results["meta"] = {"n": n, "dim": dim, "seed": seed, "smoke": smoke,
                       "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S")}
    path = _append_result(results)
    print(f"bench_disk: appended run entry to {path}", flush=True)
    assert results["tiered_serving"]["recall"] >= recall_bar, \
        f"three-tier recall@10 below bar: {results['tiered_serving']}"
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale CI variant (tiny dataset, no "
                         "build comparison)")
    ap.add_argument("--n", type=int, default=None)
    ap.add_argument("--dim", type=int, default=None)
    args = ap.parse_args()
    if args.smoke:
        main(n=args.n or 1200, dim=args.dim or 16, smoke=True,
             recall_bar=0.7)
    else:
        main(n=args.n or 6000, dim=args.dim or 32)
