"""Paper Fig. 11: GPU-CPU-disk three-tier framework.

(a)/(b): partitioned build (bounded memory window) vs monolithic, and its
search quality. (c): the flagship larger-than-memory serving workload —
an end-to-end streaming search+insert run through ``SVFusionEngine`` with
a disk-backed capacity tier whose host window holds only 1/4 of the
dataset, reporting QPS, per-query latency percentiles (computed over
per-query, not per-batch, latencies, across enough batches that p95 and
p99 land in different batches), executor rounds/dispatches per query,
speculation hit-rate, recall@10 and per-tier hit/miss rates. A
concurrency sweep drives 1/2/4/8 closed-loop streams through the
engine's cross-query coalescer (``qps_vs_streams``), and a paired probe
records the device-cache miss rate with the WAVP cascade-promote rule
off vs on.

Every run appends a machine-readable entry to
``results/pod256/bench_disk.json`` so the bench trajectory is trackable
across PRs (rotated: at most ``keep_per_key`` entries stay per config
key, the overflow archives under ``results/pod256/archive/``).
``--smoke`` runs a seconds-scale variant for CI; ``--gate`` compares the
fresh entry against the previous entry with the SAME config key (shape +
window + PQ mode — a PQ-on run never gates against an exact-mode
baseline) and fails on a >20% search-QPS regression or a >0.02 recall
drop, so perf changes are gated mechanically (``make bench-smoke`` runs
the exact-mode AND PQ-on smoke configs). QPS gate checks judge the
median of up to three steady-state re-samples (``qps_samples`` in the
entry): this box's QPS is bimodal between identical runs, and one
scheduler hiccup must not read as a regression. PQ-on runs additionally
assert the fused executor's dispatch budget (<= 4 device dispatches per
query; the per-round executor needed ~7-10). ``--pq`` serves through the
device-resident PQ code lane (quant.py: ADC scan + tier-cascade exact
re-rank); ``--scale`` runs the ≥10x memmap-built scale-up preset with PQ
on and records per-tier byte footprints. Every default/smoke run also
records ``wal_overhead`` (core/wal.py durability tax: paired WAL-on vs
WAL-off insert throughput, median of 3); ``--gate`` additionally fails
when ``wal_overhead_pct`` exceeds 15%.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import threading
import time

import jax
import numpy as np

from benchmarks.common import csv_row, exact_topk, recall
from repro.core.build import build_graph, build_index
from repro.core.engine import EngineConfig, SVFusionEngine
from repro.core.search import brute_force_topk, recall_at_k, search_batch
from repro.core.types import SearchParams
from repro.utils import percentile

RESULTS_DIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "results", "pod256")


def config_key(meta: dict) -> str:
    """Comparability key for bench entries: two runs gate against each
    other only when dataset shape, window fraction and PQ mode all match
    (a PQ-on run must never gate against an exact-mode baseline, nor a
    scale run against the toy sample). Entries written before this key
    existed lack the pq/scale fields; the defaults make their computed
    key equal to a fresh exact-mode run of the same shape, so history
    stays comparable across the cutover. The same convention covers the
    filtered-search fields (``filter``/``filter_sel``, bench_filtered.py):
    legacy entries lack them and default to the unfiltered key."""
    key = ("smoke{}-n{}-d{}-w{}-pq{}-scale{}".format(
        int(bool(meta.get("smoke"))), meta.get("n"), meta.get("dim"),
        meta.get("window_frac", 4), int(bool(meta.get("pq"))),
        int(bool(meta.get("scale")))))
    if meta.get("filter"):
        key += "-filt{}-sel{}".format(meta["filter"],
                                      meta.get("filter_sel"))
    return key


def _append_result(entry: dict, path=None, keep_per_key: int = 10):
    """Append one run entry to the pod256 trajectory file (JSON list),
    rotating old entries out: at most ``keep_per_key`` entries stay per
    config key (append-only growth was unbounded); the overflow moves to
    ``results/pod256/archive/`` so the full history survives."""
    path = path or os.path.join(RESULTS_DIR, "bench_disk.json")
    os.makedirs(os.path.dirname(path), exist_ok=True)
    hist = []
    if os.path.exists(path):
        try:
            with open(path) as f:
                hist = json.load(f)
        except (json.JSONDecodeError, OSError):
            hist = []
    hist.append(entry)
    # rotate: keep the newest keep_per_key per key, archive the rest
    counts: dict = {}
    keep, archived = [], []
    for e in reversed(hist):
        k = config_key(e.get("meta", {}))
        counts[k] = counts.get(k, 0) + 1
        (keep if counts[k] <= keep_per_key else archived).append(e)
    keep.reverse()
    archived.reverse()
    if archived:
        apath = os.path.join(os.path.dirname(path), "archive",
                             os.path.basename(path))
        os.makedirs(os.path.dirname(apath), exist_ok=True)
        old = []
        if os.path.exists(apath):
            try:
                with open(apath) as f:
                    old = json.load(f)
            except (json.JSONDecodeError, OSError):
                old = []
        with open(apath, "w") as f:
            json.dump(old + archived, f, indent=2, sort_keys=True)
    with open(path, "w") as f:
        json.dump(keep, f, indent=2, sort_keys=True)
    return path


def _median(xs):
    return sorted(xs)[len(xs) // 2]


def qps_floor(meta: dict, qps_tolerance=0.2, path=None):
    """The QPS the next run must clear to pass the gate: (1 - tol) x the
    previous comparable entry's search_qps, or None without a predecessor.
    Computed BEFORE the run so the bench can re-sample while the engine
    is still open (``check_gate`` runs after teardown)."""
    path = path or os.path.join(RESULTS_DIR, "bench_disk.json")
    if not os.path.exists(path):
        return None
    try:
        with open(path) as f:
            hist = json.load(f)
    except (json.JSONDecodeError, OSError):
        return None
    key = config_key(meta)
    for e in reversed(hist):
        # skip malformed / pre-cutover entries (missing sections or the
        # fields the comparison needs) instead of KeyError-ing on them
        try:
            if config_key(e.get("meta", {})) == key:
                return (1.0 - qps_tolerance) \
                    * e["tiered_serving"]["search_qps"]
        except (KeyError, TypeError):
            continue
    return None


def check_gate(path=None, qps_tolerance=0.2, recall_tolerance=0.02):
    """Mechanical perf gate: compare the newest entry against the previous
    one with the same config key (``config_key`` — shape + window + PQ
    mode). Returns a list of failure strings (empty = pass); no comparable
    predecessor passes trivially.

    QPS on this class of box is bimodal (+-25% between identical runs), so
    a regression is declared on the MEDIAN of the entry's re-sampled
    steady-state measurements (``qps_samples``, recorded by the bench when
    the first pass lands under the floor) — a single scheduler hiccup
    cannot fail the gate. Recall comparisons never re-sample: recall is
    deterministic given the seed."""
    path = path or os.path.join(RESULTS_DIR, "bench_disk.json")
    with open(path) as f:
        hist = json.load(f)
    if len(hist) < 2:
        return []
    new = hist[-1]
    key = config_key(new.get("meta", {}))
    prev = None
    for e in reversed(hist[:-1]):
        # an entry only qualifies as the baseline when it carries every
        # field the comparison reads — legacy/malformed entries (e.g.
        # written before the filtered-search fields existed) are skipped,
        # never KeyError-ed on
        try:
            ok = (config_key(e.get("meta", {})) == key
                  and "search_qps" in e["tiered_serving"]
                  and "recall" in e["tiered_serving"])
        except (KeyError, TypeError):
            ok = False
        if ok:
            prev = e
            break
    if prev is None:
        return []
    po, no = prev["tiered_serving"], new["tiered_serving"]
    fails = []
    floor = (1.0 - qps_tolerance) * po["search_qps"]
    samples = no.get("qps_samples") or [no["search_qps"]]
    if no["search_qps"] < floor and _median(samples) < floor:
        fails.append(
            f"search QPS regressed >{qps_tolerance:.0%}: "
            f"{po['search_qps']:.1f} -> {no['search_qps']:.1f} "
            f"(median of {len(samples)} sample(s) "
            f"{_median(samples):.1f} < floor {floor:.1f})")
    if no["recall"] < po["recall"] - recall_tolerance:
        fails.append(
            f"recall@10 dropped >{recall_tolerance}: "
            f"{po['recall']:.3f} -> {no['recall']:.3f}")
    return fails


def _build_benchmarks(vecs, queries, sp, results, seed):
    # (a) construction: monolithic vs partitioned (bounded-window merge)
    t0 = time.perf_counter()
    g1 = build_graph(vecs, 16, n_partitions=1)
    jax.block_until_ready(g1.nbrs)
    t_mono = time.perf_counter() - t0
    t0 = time.perf_counter()
    g4 = build_graph(vecs, 16, n_partitions=4, cross_samples=256)
    jax.block_until_ready(g4.nbrs)
    t_part = time.perf_counter() - t0
    csv_row("fig11_build_monolithic", t_mono * 1e6, seconds=t_mono)
    csv_row("fig11_build_partitioned4", t_part * 1e6, seconds=t_part)
    results["build"] = {"monolithic_s": t_mono, "partitioned_s": t_part}

    # (b) search quality of the partitioned build
    st = build_index(vecs, degree=16, cache_slots=512, n_max=1 << 13,
                     n_partitions=4, cross_samples=256)
    res = search_batch(st, queries, jax.random.PRNGKey(1), sp)
    truth, _ = brute_force_topk(st.graph, queries, 10)
    rec = float(recall_at_k(res.ids, truth))
    csv_row("fig11_partitioned_recall", 0.0, recall=rec)
    results["partitioned_recall"] = rec


def _concurrency_sweep(eng, dim, rng, *, streams=(1, 2, 4, 8),
                       req_queries=8, reqs_per_stream=12):
    """Closed-loop concurrency sweep through the cross-query coalescer:
    each stream submits one ``req_queries``-row request at a time and
    waits for it, so S streams offer up to S concurrent requests and the
    coalescer merges them into shared executor dispatches. Reports
    aggregate QPS per stream count."""
    # warm every power-of-two micro-batch bucket the coalescer can emit
    # (compile outside the timed region; update_cache=False bypasses the
    # coalescer for a deterministic shape)
    b = req_queries
    while b <= req_queries * max(streams):
        eng.search(rng.normal(size=(b, dim)).astype(np.float32),
                   update_cache=False)
        b *= 2
    out = []
    for s in streams:
        qs = [rng.normal(size=(req_queries, dim)).astype(np.float32)
              for _ in range(s)]
        errors: list = []

        def work(q):
            try:
                for _ in range(reqs_per_stream):
                    eng.search(q)
            except Exception as e:  # pragma: no cover
                errors.append(e)

        ths = [threading.Thread(target=work, args=(qs[i],))
               for i in range(s)]
        t0 = time.perf_counter()
        for t in ths:
            t.start()
        for t in ths:
            t.join()
        dt = time.perf_counter() - t0
        if errors:
            raise errors[0]
        out.append({"streams": s,
                    "qps": s * reqs_per_stream * req_queries / dt})
    return out


def _miss_rate_probe(vecs, sp, seed, *, batches, query_batch, window,
                     cascade_promote):
    """Device-cache miss rate after ``batches`` identical search batches,
    with the WAVP cascade-promote rule on or off (satellite ablation)."""
    rng = np.random.default_rng(seed + 7)
    n = len(vecs)
    with tempfile.TemporaryDirectory() as td:
        eng = SVFusionEngine(vecs, EngineConfig(
            degree=16, cache_slots=512, capacity=2 * n,
            disk_path=td, disk_capacity=2 * n, host_window=window,
            search=sp, seed=seed, coalesce=False,
            wavp_cascade_promote=cascade_promote))
        try:
            for _ in range(batches):
                eng.search(rng.normal(size=(query_batch, vecs.shape[1]))
                           .astype(np.float32))
            return eng.stats()["miss_rate"]
        finally:
            eng.close()


def _streaming_tiered(vecs, sp, results, seed, rounds=6, insert_chunk=128,
                      query_batch=64, meas_batches=24, pq=False,
                      sweep=True, probe_ablation=True, engine_kw=None,
                      floor=None):
    """(c) end-to-end three-tier serving: dataset ≥4x the host window.
    ``pq=True`` serves through the device-resident code lane (ADC scan +
    tier-cascade exact re-rank) and records the per-tier byte footprint.
    ``sweep``/``probe_ablation`` gate the auxiliary measurements (the
    scale preset skips them — its point is footprint, not concurrency)."""
    rng = np.random.default_rng(seed + 1)
    n, dim = vecs.shape
    n_seed = n // 2                       # half preloaded, rest streamed in
    # one untimed warmup insert round precedes the timed rounds (see
    # cold-start below), so the streamed total is rounds+1 chunks
    n_final = n_seed + (rounds + 1) * insert_chunk
    window = n_final // 4                 # dataset is >=4x the host window
    with tempfile.TemporaryDirectory() as td:
        # m = dim/2 keeps the device code footprint at exactly
        # m/(4·dim) = 1/8 of full-coverage fp32 across bench dims
        # (m=16 at the flagship dim=32); engine_kw overrides win
        cfg_kw = dict(degree=16, cache_slots=512, capacity=2 * n,
                      disk_path=td, disk_capacity=2 * n,
                      host_window=window, search=sp, seed=seed,
                      pq_enabled=pq, pq_m=dim // 2)
        cfg_kw.update(engine_kw or {})
        eng = SVFusionEngine(vecs[:n_seed], EngineConfig(**cfg_kw))
        try:
            # cold-start warmup (paper §4.4): compile the executor's
            # dispatch pipeline at serving shape AND let the placement
            # tiers converge before the timed loop, so QPS reflects
            # steady-state serving, not one-time jit compile or the
            # cache's cold-start churn. One warmup INSERT round is part
            # of it: the insert path's candidate search compiles at the
            # chunk batch size (and, PQ mode, the incremental-encode +
            # post-insert bucket shapes) — without it those one-time
            # compiles land in the first timed interleaved batches and
            # the 2-6-batch interleaved QPS reads ~5x low
            t0 = time.perf_counter()
            mirror_ids = list(range(n_seed))
            cursor = n_seed
            for _ in range(3):
                eng.search(rng.normal(size=(query_batch, dim))
                           .astype(np.float32))
            warm_ids = eng.insert(vecs[cursor:cursor + insert_chunk])
            mirror_ids.extend(int(i) for i in warm_ids)
            cursor += len(warm_ids)
            for _ in range(3):
                eng.search(rng.normal(size=(query_batch, dim))
                           .astype(np.float32))
            cold_start_s = time.perf_counter() - t0
            recs, s_lat, i_lat = [], [], []
            n_q = n_i = 0
            n_interleaved = 0
            for _ in range(rounds):
                part = vecs[cursor:cursor + insert_chunk]
                if len(part):
                    t0 = time.perf_counter()
                    ids = eng.insert(part)
                    i_lat.append(time.perf_counter() - t0)
                    n_i += len(ids)
                    mirror_ids.extend(int(i) for i in ids)
                    cursor += len(part)
                q = rng.normal(size=(query_batch, dim)).astype(np.float32)
                t0 = time.perf_counter()
                found, _ = eng.search(q)
                s_lat.append(time.perf_counter() - t0)
                n_q += len(q)
                mid = np.asarray(mirror_ids, np.int64)
                truth = exact_topk(mid, vecs[:cursor], q, 10)
                recs.append(recall(found[:, :10], truth))
            n_interleaved = len(s_lat)
            # re-warm at the post-insert dataset shape (the stream grew n,
            # which can shift the executor's unique-row bucket): compiles
            # must land outside the timed region
            for _ in range(4):
                eng.search(rng.normal(size=(query_batch, dim))
                           .astype(np.float32))
            # steady-state search measurement: enough batches that the
            # tail percentiles are not degenerate (p95 == p99 was an
            # artifact of sampling 6 batches)
            for _ in range(meas_batches):
                q = rng.normal(size=(query_batch, dim)).astype(np.float32)
                t0 = time.perf_counter()
                eng.search(q)
                s_lat.append(time.perf_counter() - t0)
                n_q += query_batch
            # gate robustness: when the first steady-state pass lands
            # under the predecessor's floor (``qps_floor``), re-sample up
            # to twice more while the engine is still warm and let the
            # gate judge the MEDIAN — one scheduler hiccup on this
            # bimodal box must not read as a >20% regression. Every pass
            # is recorded in the run entry (qps_samples) either way.
            qps_samples = [meas_batches * query_batch
                           / max(sum(s_lat[-meas_batches:]), 1e-9)]
            while (floor is not None and len(qps_samples) < 3
                   and _median(qps_samples) < floor):
                lat_r = []
                for _ in range(meas_batches):
                    q = rng.normal(size=(query_batch, dim)) \
                        .astype(np.float32)
                    t0 = time.perf_counter()
                    eng.search(q)
                    lat_r.append(time.perf_counter() - t0)
                s_lat.extend(lat_r)
                n_q += meas_batches * query_batch
                qps_samples.append(meas_batches * query_batch
                                   / max(sum(lat_r), 1e-9))
            st = eng.stats()
            # per-query latency: every query in a batch observes the
            # batch's shared pipeline, so its latency is lat/batch_size
            # (batches are equal-sized, so percentiles over this per-batch
            # population ARE the per-query percentiles); the degeneracy
            # fix is the raised sample count, which puts p95 and p99 in
            # different batches
            pq_ms = np.asarray(s_lat) / query_batch * 1e3
            sweep_out = _concurrency_sweep(eng, dim, rng) if sweep else None
            out = {
                "recall": float(np.mean(recs)),
                "search_qps": n_q / max(sum(s_lat), 1e-9),
                # PR-2-comparable figure: only the batches interleaved
                # with the insert stream (the whole PR 2 sample), so
                # cross-PR QPS deltas are not a measurement-mix artifact
                "search_qps_interleaved":
                    n_interleaved * query_batch
                    / max(sum(s_lat[:n_interleaved]), 1e-9),
                "insert_qps": n_i / max(sum(i_lat), 1e-9),
                "search_batches_timed": len(s_lat),
                "search_p50_ms_per_query": percentile(pq_ms, 50),
                "search_p95_ms_per_query": percentile(pq_ms, 95),
                "search_p99_ms_per_query": percentile(pq_ms, 99),
                "rounds_per_query": st["search_rounds_per_batch"],
                # single source: the engine's per-result dispatch counter
                # threaded through TieredSearchResult (acceptance metric
                # of the fused multi-round executor)
                "dispatches_per_query": st["dispatches_per_query"],
                "topo_hit_rate": st["topo_hit_rate"],
                "qps_samples": qps_samples,
                "spec_hit_rate": st["spec_hit_rate"],
                "spec_rank_resolved": st.get("spec_rank_resolved"),
                "coalesce_batch_mean": st["coalesce_batch_mean"],
                "cold_start_s": cold_start_s,
                "beam": sp.beam,
                "hop_budget": sp.max_iters,
                "device_miss_rate": st["miss_rate"],
                "host_miss_rate": st["host_miss_rate"],
                "device_hits": st["hits"],
                "host_hits": st["host_hits"],
                "disk_reads": st["disk_reads"],
                "prefetched": st["prefetched"],
                "window_over_dataset": window / cursor,
            }
            if sweep_out is not None:
                out["qps_vs_streams"] = sweep_out
            # per-tier byte footprint (ISSUE acceptance: device codes at
            # <= 1/8 of the exact full-coverage fp32 equivalent)
            out["bytes_per_tier"] = st["bytes_per_tier"]
            out["device_exact_equiv_bytes"] = st["device_exact_equiv_bytes"]
            if pq:
                out["device_vector_bytes"] = st["device_vector_bytes"]
                out["device_footprint_ratio"] = st["device_footprint_ratio"]
                out["pq_m"] = st["pq_m"]
                out["pq_bits"] = st["pq_bits"]
                out["rerank_depth"] = st["rerank_depth"]
                out["pq_encoded_incremental"] = st["pq_encoded_incremental"]
            assert cursor >= 4 * window    # larger-than-window guarantee
        finally:
            eng.close()
    if probe_ablation:
        # paired ablation: the same search workload with the cascade-
        # promote rule off (the pre-fix clock freeze) vs on
        probe = dict(batches=max(8, rounds + meas_batches // 2),
                     query_batch=query_batch, window=window)
        out["device_miss_rate_cascade_promote_off"] = _miss_rate_probe(
            vecs[:n_seed], sp, seed, cascade_promote=False, **probe)
        out["device_miss_rate_cascade_promote_on"] = _miss_rate_probe(
            vecs[:n_seed], sp, seed, cascade_promote=True, **probe)
    csv_row("fig11_tiered_serving", 0.0, **{
        k: v for k, v in out.items() if not isinstance(v, (list, dict))})
    results["tiered_serving"] = out


def _wal_overhead_probe(vecs, sp, seed, *, rounds, insert_chunk,
                        samples=3):
    """Durability cost probe: median-of-``samples`` insert throughput with
    the write-ahead log on vs off, over identical fresh engines and
    identical insert streams. ``wal_overhead_pct`` is the gated figure
    (<= 15%): the WAL adds one unbuffered frame write per insert batch
    plus a group-commit fsync every ``wal_group_commit`` batches, so the
    overhead should stay single-digit — a blowout means the prepare/apply
    split regressed into extra store traffic."""
    n, dim = vecs.shape
    n_seed = n // 2

    def run(wal_on):
        with tempfile.TemporaryDirectory() as td:
            eng = SVFusionEngine(vecs[:n_seed], EngineConfig(
                degree=16, cache_slots=512, capacity=2 * n,
                disk_path=td, disk_capacity=2 * n,
                host_window=max(64, n // 4), search=sp, seed=seed,
                coalesce=False, prefetch=False, wal_enabled=wal_on,
                snapshot_every_epochs=0))
            try:
                cursor = n_seed
                # warm round: compile the insert path outside the timing
                eng.insert(vecs[cursor:cursor + insert_chunk])
                cursor += insert_chunk
                cnt = 0
                t0 = time.perf_counter()
                for _ in range(rounds):
                    part = vecs[cursor:cursor + insert_chunk]
                    if not len(part):
                        break
                    eng.insert(part)
                    cnt += len(part)
                    cursor += len(part)
                return cnt / max(time.perf_counter() - t0, 1e-9)
            finally:
                eng.close()

    # interleave the paired runs (alternating order) so slow drift in
    # background load lands on both sides instead of biasing whichever
    # mode happened to run last
    ons, offs = [], []
    for i in range(samples):
        for wal_on in ((True, False) if i % 2 == 0 else (False, True)):
            (ons if wal_on else offs).append(run(wal_on))
    on, off = _median(ons), _median(offs)
    return {"insert_qps_wal_on": on, "insert_qps_wal_off": off,
            "wal_overhead_pct": max(0.0, (off - on) / off * 100.0)}


def main(n=6000, dim=32, seed=0, *, smoke=False, recall_bar=0.8,
         gate=False, pq=False):
    rng = np.random.default_rng(seed)
    vecs = rng.normal(size=(n, dim)).astype(np.float32)
    queries = rng.normal(size=(64, dim)).astype(np.float32)
    sp = SearchParams(k=10, pool=64, max_iters=96)
    results = {}
    meta = {"n": n, "dim": dim, "seed": seed, "smoke": smoke,
            "pq": pq, "scale": False, "window_frac": 4}
    if not smoke:   # build comparison is minutes-scale; skip in CI smoke
        _build_benchmarks(vecs, queries, sp, results, seed)
    _streaming_tiered(vecs, sp, results, seed,
                      rounds=2 if smoke else 6,
                      insert_chunk=64 if smoke else 128,
                      query_batch=32 if smoke else 64,
                      meas_batches=20 if smoke else 24,
                      pq=pq, floor=qps_floor(meta) if gate else None)
    results["wal_overhead"] = _wal_overhead_probe(
        vecs, sp, seed,
        rounds=4, insert_chunk=64 if smoke else 128)
    results["meta"] = dict(meta,
                           timestamp=time.strftime("%Y-%m-%dT%H:%M:%S"))
    path = _append_result(results)
    print(f"bench_disk: appended run entry to {path} "
          f"(key {config_key(results['meta'])})", flush=True)
    wal_pct = results["wal_overhead"]["wal_overhead_pct"]
    print(f"  wal_overhead_pct: {wal_pct:.1f}% "
          f"(insert QPS {results['wal_overhead']['insert_qps_wal_on']:.0f} "
          f"on / {results['wal_overhead']['insert_qps_wal_off']:.0f} off)",
          flush=True)
    if gate and wal_pct > 15.0:
        print(f"bench gate FAIL: WAL insert overhead {wal_pct:.1f}% > 15% "
              f"(median of 3 paired runs)", file=sys.stderr)
        raise SystemExit(1)
    assert results["tiered_serving"]["recall"] >= recall_bar, \
        f"three-tier recall@10 below bar: {results['tiered_serving']}"
    if pq:
        # fused multi-round executor acceptance: the topology tier keeps
        # the walk on device, so a query costs entry + fused-loop(s) +
        # re-rank — a miss re-entry or two may push the mean past 3, but
        # 4 means the fusion is broken (per-round was ~7-10)
        dpq = results["tiered_serving"]["dispatches_per_query"]
        assert dpq <= 4.0, \
            f"PQ-on dispatches/query {dpq:.2f} > 4: fused executor is " \
            f"not fusing (topo hit rate " \
            f"{results['tiered_serving']['topo_hit_rate']:.3f})"
    if gate:
        fails = check_gate(path)
        if fails:
            for f in fails:
                print(f"bench gate FAIL: {f}", file=sys.stderr)
            raise SystemExit(1)
        print("bench gate: pass (no >20% QPS / >0.02 recall regression)")
    return results


def _memmap_dataset(path, n, dim, seed, chunk=8192):
    """Build the scale dataset memmap-backed, never holding it all in
    RAM (the whole point of the preset: the data layout is the one the
    disk tier serves, only a chunk's worth of rows transits memory)."""
    mm = np.memmap(path, np.float32, "w+", shape=(n, dim))
    rng = np.random.default_rng(seed)
    for s in range(0, n, chunk):
        e = min(s + chunk, n)
        mm[s:e] = rng.normal(size=(e - s, dim)).astype(np.float32)
    mm.flush()
    return mm


def main_scale(n=60000, dim=32, seed=0, *, recall_bar=0.9, gate=False):
    """Scale-up preset (`--scale`): a dataset ≥10x the default sample,
    memmap-built, served through the PQ code lane — the ROADMAP "beyond
    toy sizes" item. The device codes (n·m bytes) give full-coverage
    device-side distance evaluation where fp32 vectors (n·D·4) would not
    fit the device budget; per-tier byte footprints land in the entry.
    Skips the build comparison, concurrency sweep and miss-rate ablation:
    this preset measures footprint + serving at scale, nothing else.

    Graph/search knobs scale with the dataset (the toy sample's
    degree=16 / pool=64 / 96 hops drop to ~0.4 recall at 30k live
    vectors on random gaussian data): degree 32, partitioned build with
    1024 cross-partition candidate columns, pool 128, 256-hop budget at
    beam 32, re-rank depth 48."""
    sp = SearchParams(k=10, pool=128, max_iters=256, beam=32)
    results = {}
    meta = {"n": n, "dim": dim, "seed": seed, "smoke": False,
            "pq": True, "scale": True, "window_frac": 4}
    with tempfile.TemporaryDirectory() as td:
        vecs = _memmap_dataset(os.path.join(td, "scale.f32"), n, dim, seed)
        _streaming_tiered(
            vecs, sp, results, seed, rounds=2, insert_chunk=256,
            query_batch=64, meas_batches=8, pq=True, sweep=False,
            probe_ablation=False,
            floor=qps_floor(meta) if gate else None,
            # partitioned build: the monolithic O(n^2) GEMM at this n
            # would dominate the preset's runtime (and its memory is the
            # bounded-window story the paper tells anyway)
            engine_kw={"build_partitions": 4, "build_cross_samples": 1024,
                       "degree": 32, "rerank_depth": 48})
    results["meta"] = dict(meta,
                           timestamp=time.strftime("%Y-%m-%dT%H:%M:%S"))
    path = _append_result(results)
    ts = results["tiered_serving"]
    print(f"bench_disk --scale: appended run entry to {path} "
          f"(key {config_key(results['meta'])})", flush=True)
    print(f"  bytes_per_tier: {ts['bytes_per_tier']}", flush=True)
    print(f"  device_footprint_ratio: {ts['device_footprint_ratio']:.4f} "
          f"(codes vs full-coverage fp32)", flush=True)
    assert ts["recall"] >= recall_bar, \
        f"scale recall@10 below bar: {ts['recall']}"
    if gate:
        fails = check_gate(path)
        if fails:
            for f in fails:
                print(f"bench gate FAIL: {f}", file=sys.stderr)
            raise SystemExit(1)
        print("bench gate: pass (no >20% QPS / >0.02 recall regression)")
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale CI variant (tiny dataset, no "
                         "build comparison)")
    ap.add_argument("--gate", action="store_true",
                    help="fail on >20%% QPS or >0.02 recall regression "
                         "vs the previous comparable entry")
    ap.add_argument("--pq", action="store_true",
                    help="serve through the PQ code lane (device-resident "
                         "ADC scan + tier-cascade exact re-rank)")
    ap.add_argument("--scale", action="store_true",
                    help="scale-up preset: >=10x dataset, memmap-built, "
                         "PQ on, per-tier byte footprints recorded")
    ap.add_argument("--n", type=int, default=None)
    ap.add_argument("--dim", type=int, default=None)
    args = ap.parse_args()
    if args.scale:
        main_scale(n=args.n or 60000, dim=args.dim or 32, gate=args.gate)
    elif args.smoke:
        main(n=args.n or 1200, dim=args.dim or 16, smoke=True,
             recall_bar=0.7, gate=args.gate, pq=args.pq)
    else:
        main(n=args.n or 6000, dim=args.dim or 32, gate=args.gate,
             pq=args.pq)
