"""Paper Fig. 11: GPU-CPU-disk three-tier framework — partitioned build
(bounded memory window) + disk-tier search vs the in-memory two-tier path."""
from __future__ import annotations

import tempfile
import time

import jax
import numpy as np

from benchmarks.common import csv_row
from repro.core.build import build_graph, build_index
from repro.core.search import brute_force_topk, recall_at_k, search_batch
from repro.core.tiers import DiskTier, TieredStore
from repro.core.types import SearchParams


def main(n=6000, dim=32, seed=0):
    rng = np.random.default_rng(seed)
    vecs = rng.normal(size=(n, dim)).astype(np.float32)
    queries = rng.normal(size=(64, dim)).astype(np.float32)
    sp = SearchParams(k=10, pool=64, max_iters=96)
    results = {}

    # (a) construction: monolithic vs partitioned (bounded-window merge)
    t0 = time.perf_counter()
    g1 = build_graph(vecs, 16, n_partitions=1)
    jax.block_until_ready(g1.nbrs)
    t_mono = time.perf_counter() - t0
    t0 = time.perf_counter()
    g4 = build_graph(vecs, 16, n_partitions=4, cross_samples=256)
    jax.block_until_ready(g4.nbrs)
    t_part = time.perf_counter() - t0
    csv_row("fig11_build_monolithic", t_mono * 1e6, seconds=t_mono)
    csv_row("fig11_build_partitioned4", t_part * 1e6, seconds=t_part)
    results["build"] = {"monolithic_s": t_mono, "partitioned_s": t_part}

    # (b) search quality of the partitioned build
    st = build_index(vecs, degree=16, cache_slots=512, n_max=1 << 13,
                     n_partitions=4, cross_samples=256)
    res = search_batch(st, queries, jax.random.PRNGKey(1), sp)
    truth, _ = brute_force_topk(st.graph, queries, 10)
    rec = float(recall_at_k(res.ids, truth))
    csv_row("fig11_partitioned_recall", 0.0, recall=rec)
    results["partitioned_recall"] = rec

    # (c) disk tier: memmap store with a small host window
    with tempfile.TemporaryDirectory() as td:
        disk = DiskTier(td, capacity=n, dim=dim, degree=16)
        disk.write(np.arange(n), vecs, np.asarray(g1.nbrs[:n]))
        store = TieredStore(disk, host_slots=n // 4)
        f_lambda = np.asarray(np.log1p(np.asarray(g1.e_in[:n], np.float64)))
        t0 = time.perf_counter()
        for _ in range(4):
            ids = rng.integers(0, n, 512)
            store.fetch(ids, f_lambda)
        dt = time.perf_counter() - t0
        csv_row("fig11_disk_fetch", dt / (4 * 512) * 1e6,
                miss_rate=store.miss_rate)
        results["disk_miss_rate"] = store.miss_rate
    return results


if __name__ == "__main__":
    main()
