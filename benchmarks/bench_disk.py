"""Paper Fig. 11: GPU-CPU-disk three-tier framework.

(a)/(b): partitioned build (bounded memory window) vs monolithic, and its
search quality. (c): the flagship larger-than-memory serving workload —
an end-to-end streaming search+insert run through ``SVFusionEngine`` with
a disk-backed capacity tier whose host window holds only 1/4 of the
dataset, reporting QPS, recall@10 and per-tier hit/miss rates.
"""
from __future__ import annotations

import tempfile
import time

import jax
import numpy as np

from benchmarks.common import csv_row, exact_topk, recall
from repro.core.build import build_graph, build_index
from repro.core.engine import EngineConfig, SVFusionEngine
from repro.core.search import brute_force_topk, recall_at_k, search_batch
from repro.core.types import SearchParams


def _build_benchmarks(vecs, queries, sp, results, seed):
    # (a) construction: monolithic vs partitioned (bounded-window merge)
    t0 = time.perf_counter()
    g1 = build_graph(vecs, 16, n_partitions=1)
    jax.block_until_ready(g1.nbrs)
    t_mono = time.perf_counter() - t0
    t0 = time.perf_counter()
    g4 = build_graph(vecs, 16, n_partitions=4, cross_samples=256)
    jax.block_until_ready(g4.nbrs)
    t_part = time.perf_counter() - t0
    csv_row("fig11_build_monolithic", t_mono * 1e6, seconds=t_mono)
    csv_row("fig11_build_partitioned4", t_part * 1e6, seconds=t_part)
    results["build"] = {"monolithic_s": t_mono, "partitioned_s": t_part}

    # (b) search quality of the partitioned build
    st = build_index(vecs, degree=16, cache_slots=512, n_max=1 << 13,
                     n_partitions=4, cross_samples=256)
    res = search_batch(st, queries, jax.random.PRNGKey(1), sp)
    truth, _ = brute_force_topk(st.graph, queries, 10)
    rec = float(recall_at_k(res.ids, truth))
    csv_row("fig11_partitioned_recall", 0.0, recall=rec)
    results["partitioned_recall"] = rec


def _streaming_tiered(vecs, sp, results, seed, rounds=6, insert_chunk=128,
                      query_batch=64):
    """(c) end-to-end three-tier serving: dataset ≥4x the host window."""
    rng = np.random.default_rng(seed + 1)
    n, dim = vecs.shape
    n_seed = n // 2                       # half preloaded, rest streamed in
    n_final = n_seed + rounds * insert_chunk
    window = n_final // 4                 # dataset is >=4x the host window
    with tempfile.TemporaryDirectory() as td:
        eng = SVFusionEngine(vecs[:n_seed], EngineConfig(
            degree=16, cache_slots=512, capacity=2 * n,
            disk_path=td, disk_capacity=2 * n, host_window=window,
            search=sp, seed=seed))
        try:
            mirror_ids = list(range(n_seed))
            recs, s_lat, i_lat = [], [], []
            n_q = n_i = 0
            cursor = n_seed
            for _ in range(rounds):
                part = vecs[cursor:cursor + insert_chunk]
                if len(part):
                    t0 = time.perf_counter()
                    ids = eng.insert(part)
                    i_lat.append(time.perf_counter() - t0)
                    n_i += len(ids)
                    mirror_ids.extend(int(i) for i in ids)
                    cursor += len(part)
                q = rng.normal(size=(query_batch, dim)).astype(np.float32)
                t0 = time.perf_counter()
                found, _ = eng.search(q)
                s_lat.append(time.perf_counter() - t0)
                n_q += len(q)
                mid = np.asarray(mirror_ids, np.int64)
                truth = exact_topk(mid, vecs[:cursor], q, 10)
                recs.append(recall(found[:, :10], truth))
            st = eng.stats()
            out = {
                "recall": float(np.mean(recs)),
                "search_qps": n_q / max(sum(s_lat), 1e-9),
                "insert_qps": n_i / max(sum(i_lat), 1e-9),
                "device_miss_rate": st["miss_rate"],
                "host_miss_rate": st["host_miss_rate"],
                "device_hits": st["hits"],
                "host_hits": st["host_hits"],
                "disk_reads": st["disk_reads"],
                "prefetched": st["prefetched"],
                "window_over_dataset": window / cursor,
            }
            assert cursor >= 4 * window    # larger-than-window guarantee
            csv_row("fig11_tiered_serving", 0.0, **out)
            results["tiered_serving"] = out
        finally:
            eng.close()


def main(n=6000, dim=32, seed=0):
    rng = np.random.default_rng(seed)
    vecs = rng.normal(size=(n, dim)).astype(np.float32)
    queries = rng.normal(size=(64, dim)).astype(np.float32)
    sp = SearchParams(k=10, pool=64, max_iters=96)
    results = {}
    _build_benchmarks(vecs, queries, sp, results, seed)
    _streaming_tiered(vecs, sp, results, seed)
    assert results["tiered_serving"]["recall"] >= 0.8, \
        f"three-tier recall@10 below bar: {results['tiered_serving']}"
    return results


if __name__ == "__main__":
    main()
