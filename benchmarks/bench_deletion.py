"""Paper Fig. 12: deletion strategies — lazy only / lazy+global
consolidation / full (lazy + localized repair + consolidation).

Deletions are spatially clustered (paper Fig. 5: KNNG neighborhoods die
together). Recall is evaluated after every deletion wave and averaged over
the stream — the paper's point is that localized repair holds recall up
*between* the rare global consolidations.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import csv_row
from repro.core import update as U
from repro.core.build import build_index
from repro.core.search import brute_force_topk, recall_at_k, search_batch
from repro.core.types import SearchParams


def main(n=6000, dim=32, delete_frac=0.25, waves=8, seed=0):
    rng = np.random.default_rng(seed)
    vecs = rng.normal(size=(n, dim)).astype(np.float32)
    sp = SearchParams(k=10, pool=64, max_iters=96)
    queries = rng.normal(size=(64, dim)).astype(np.float32)
    # spatially clustered deletions
    center = vecs[rng.integers(n)]
    del_ids = np.argsort(((vecs - center) ** 2).sum(1))[:int(n * delete_frac)]

    def eval_recall(st):
        res = search_batch(st, queries, jax.random.PRNGKey(1), sp)
        truth, _ = brute_force_topk(st.graph, queries, 10)
        return float(recall_at_k(res.ids, truth))

    # warm jit caches
    warm = build_index(vecs, degree=16, cache_slots=512, n_max=1 << 13,
                       seed=seed)
    warm = U.delete_batch(warm, del_ids[:n // waves].astype(np.int32))
    warm, _ = U.repair_affected(warm, max_repair=256)
    eval_recall(warm)
    jax.block_until_ready(U.consolidate(warm).graph.nbrs)

    results = {}
    for strategy in ("lazy", "lazy+consolidate", "full"):
        st = build_index(vecs, degree=16, cache_slots=512, n_max=1 << 13,
                         seed=seed)
        overhead = 0.0
        recalls = []
        consolidations = 0
        deleted_since = 0
        for wave in np.array_split(del_ids, waves):
            t0 = time.perf_counter()
            st = U.delete_batch(st, wave.astype(np.int32))
            deleted_since += len(wave)
            if strategy == "full":
                st, _ = U.repair_affected(st, max_repair=256)
            if strategy != "lazy" and deleted_since >= 0.2 * n:
                st = U.consolidate(st)   # paper: 20% new-deletion threshold
                deleted_since = 0
                consolidations += 1
            jax.block_until_ready(st.graph.nbrs)
            overhead += time.perf_counter() - t0
            recalls.append(eval_recall(st))
        results[strategy] = {"recall": float(np.mean(recalls)),
                             "final_recall": recalls[-1],
                             "overhead_s": overhead,
                             "consolidations": consolidations}
        csv_row(f"fig12_{strategy}", overhead * 1e6, **results[strategy])
    return results


if __name__ == "__main__":
    main()
