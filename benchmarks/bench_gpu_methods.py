"""Paper Fig. 15: CPU-GPU search methods vs dataset scale relative to
device-memory capacity (cache covers 100% .. 10% of the data)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import SVFusionAdapter, csv_row, exact_topk, recall


def run_method(name, dim, data, queries, cache_slots):
    if name == "svfusion":
        idx = SVFusionAdapter(dim, degree=16, cache_slots=cache_slots,
                              capacity=1 << 15, policy="wavp")
    elif name == "uvm_like":       # promote every miss (UVM behavior)
        idx = SVFusionAdapter(dim, degree=16, cache_slots=cache_slots,
                              capacity=1 << 15, policy="always")
    elif name == "cpu_only":       # never use the bandwidth tier
        idx = SVFusionAdapter(dim, degree=16, cache_slots=cache_slots,
                              capacity=1 << 15, policy="never")
    else:
        raise ValueError(name)
    ids = idx.insert(data)
    id2row = {int(i): r for r, i in enumerate(ids)}
    idx.search(queries[:8])   # warm
    t0 = time.perf_counter()
    found = idx.search(queries)
    dt = time.perf_counter() - t0
    truth_rows = exact_topk(np.asarray(ids), data, queries, 10)
    rec = recall(found, truth_rows)
    s = idx.stats()
    return {"qps": len(queries) / dt, "recall": rec,
            "miss_rate": s["miss_rate"],
            "transfers": s["transfers"],
            "modeled_us": s["modeled_us_per_access"]}


def main(n=5000, dim=32):
    rng = np.random.default_rng(0)
    data = rng.normal(size=(n, dim)).astype(np.float32)
    queries = rng.normal(size=(128, dim)).astype(np.float32)
    results = {}
    for frac in (1.0, 0.5, 0.25, 0.1):
        slots = max(64, int(n * frac))
        for method in ("svfusion", "uvm_like", "cpu_only"):
            r = run_method(method, dim, data, queries, slots)
            results[(frac, method)] = r
            csv_row(f"fig15_scale{int(1/frac)}x_{method}",
                    1e6 / max(r["qps"], 1e-9), **r)
    return results


if __name__ == "__main__":
    main()
