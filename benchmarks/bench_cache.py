"""Paper Fig. 9 + 10: replacement strategies (WAVP vs LRU/LFU/LRFU vs
no-WAVP) and GPU-memory-ratio sweep."""
from __future__ import annotations

import numpy as np

from benchmarks.common import SVFusionAdapter, csv_row, run_workload
from repro.train.data import sliding_window


def run_policy(policy, n, dim, cache_slots, max_steps=50):
    idx = SVFusionAdapter(dim, degree=16, cache_slots=cache_slots,
                          capacity=1 << 15, policy=policy)
    wl = sliding_window(n=n, dim=dim, t_max=40)
    m = run_workload(idx, wl, max_steps=max_steps,
                     name=f"cache/{policy}")
    return m.summary()


def main(n=4000, dim=32):
    results = {}
    # Fig 9: replacement strategies at fixed cache size
    for policy in ("wavp", "lrfu", "lfu", "lru", "never"):
        s = run_policy(policy, n, dim, cache_slots=512)
        results[("policy", policy)] = s
        csv_row(f"fig9_policy_{policy}", 1e6 / max(s["search_qps"], 1e-9),
                recall=s["recall"], search_qps=s["search_qps"],
                p99_ms=s["search_p99_ms"], miss_rate=s.get("miss_rate", 0),
                modeled_us=s.get("modeled_us", 0))
    # Fig 10: memory-ratio sweep (cache slots as % of live set ~2000)
    for ratio in (0.2, 0.4, 0.6, 0.8, 1.0):
        slots = int(2000 * ratio)
        s = run_policy("wavp", n, dim, cache_slots=slots)
        results[("ratio", ratio)] = s
        csv_row(f"fig10_ratio_{int(ratio*100)}",
                1e6 / max(s["search_qps"], 1e-9),
                search_qps=s["search_qps"], miss_rate=s.get("miss_rate", 0))
    return results


if __name__ == "__main__":
    main()
