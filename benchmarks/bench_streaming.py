"""Paper Fig. 7: recall / search throughput / insert throughput / miss rate
across streaming workloads × methods."""
from __future__ import annotations

import numpy as np

from benchmarks.common import SVFusionAdapter, csv_row, run_workload
from repro.core.baselines import HNSW, Vamana, CagraStatic
from repro.train.data import WORKLOADS


def make_method(method, dim):
    if method == "svfusion":
        return SVFusionAdapter(dim, degree=16, cache_slots=768,
                               capacity=1 << 15)
    if method == "hnsw":
        return HNSW(dim, M=12, ef_construction=64, ef_search=64)
    if method == "vamana":
        return Vamana(dim, R=16, L=48)
    if method == "cagra_static":
        return CagraStatic(dim, degree=16, rebuild_every=2048)
    raise ValueError(method)


def main(n=4000, dim=32, methods=("svfusion", "hnsw", "vamana",
                                  "cagra_static"),
         workloads=("sliding_window", "expiration_time", "clustered",
                    "msturing_ih"), max_steps=60):
    results = {}
    for wname in workloads:
        for method in methods:
            if wname == "sliding_window":
                wl = WORKLOADS[wname](n=n, dim=dim, t_max=50)
            elif wname == "expiration_time":
                wl = WORKLOADS[wname](n=n, dim=dim, t_max=40)
            elif wname == "clustered":
                wl = WORKLOADS[wname](n=n, dim=dim, rounds=3)
            else:
                wl = WORKLOADS[wname](n_start=n // 8, n_final=n, dim=dim,
                                      n_ops=max_steps)
            idx = make_method(method, dim)
            m = run_workload(idx, wl, max_steps=max_steps,
                             name=f"{wname}/{method}")
            s = m.summary()
            results[(wname, method)] = s
            csv_row(f"fig7_{wname}_{method}",
                    1e6 / max(s["search_qps"], 1e-9),
                    recall=s["recall"], search_qps=s["search_qps"],
                    insert_qps=s["insert_qps"],
                    miss_rate=s.get("miss_rate", 0.0))
    return results


if __name__ == "__main__":
    main()
