"""Hop-batched frontier executor parity tests.

The executor (core/search.py) must be a pure restructuring of the greedy
beam search: with ``beam=1`` each round expands exactly one frontier
candidate, so its top-k must be *identical* to the pre-refactor per-hop
reference — for both the in-memory (device) arm and the tiered arm. The
reference below re-implements the per-hop loop with host control flow and
the same jitted distance primitives, so any drift in the executor's
select/dedup/merge logic shows up as an id mismatch.
"""
import tempfile

import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:   # no network route: replay fixed seeded examples
    from _hypothesis_shim import given, settings, st

from repro.core import cache as C
from repro.core.build import build_index, build_tiered_backend
from repro.core.search import (dedup_mask, frontier_search, search_tiered,
                               _batch_sqdist)
from repro.core.types import SearchParams
from repro.kernels.ops import gather_l2


def per_hop_reference(nbrs, alive, queries, entries, sp, dist_fn):
    """Pre-refactor per-hop greedy beam search (one expansion per device
    round), host control flow. ``dist_fn(ids [B, C]) -> [B, C]`` fp32
    distances, +inf on invalid (-1) lanes."""
    B = queries.shape[0]
    L, k, I = sp.pool, sp.k, sp.max_iters
    nbrs = np.asarray(nbrs)
    alive = np.asarray(alive)
    lanes = np.arange(B)

    pool_d = dist_fn(entries).copy()
    pool_d[~alive[np.clip(entries, 0, None)] | (entries < 0)] = np.inf
    pool_d[dedup_mask(entries)] = np.inf
    order = np.argsort(pool_d, axis=1, kind="stable")
    pool_ids = np.take_along_axis(entries, order, axis=1)
    pool_d = np.take_along_axis(pool_d, order, axis=1)
    visited = np.zeros((B, L), bool)

    for _ in range(I):
        sel = np.where(visited | ~np.isfinite(pool_d), np.inf, pool_d)
        best = np.argmin(sel, axis=1)
        active = np.isfinite(sel[lanes, best])
        if not active.any():
            break
        curr = np.where(active, pool_ids[lanes, best], -1)
        visited[lanes[active], best[active]] = True

        nb = nbrs[np.clip(curr, 0, None)]
        nb[~active] = -1
        valid = (nb >= 0) & alive[np.clip(nb, 0, None)]
        d = dist_fn(nb).copy()
        in_pool = (nb[:, :, None] == pool_ids[:, None, :]).any(-1)
        d[~valid | in_pool | dedup_mask(nb)] = np.inf

        all_ids = np.concatenate([pool_ids, nb], axis=1)
        all_d = np.concatenate([pool_d, d], axis=1)
        all_vis = np.concatenate([visited, np.zeros(nb.shape, bool)], axis=1)
        keep = np.argsort(all_d, axis=1, kind="stable")[:, :L]
        pool_ids = np.take_along_axis(all_ids, keep, axis=1)
        pool_d = np.take_along_axis(all_d, keep, axis=1)
        visited = np.take_along_axis(all_vis, keep, axis=1)

    return np.where(np.isfinite(pool_d[:, :k]), pool_ids[:, :k], -1)


def _small_problem(seed, n):
    rng = np.random.default_rng(seed)
    vecs = rng.normal(size=(n, 12)).astype(np.float32)
    queries = rng.normal(size=(4, 12)).astype(np.float32)
    sp = SearchParams(k=5, pool=16, max_iters=24, beam=1)
    entries = rng.integers(0, n, (4, sp.pool))
    return vecs, queries, sp, entries


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(60, 160), st.integers(4, 8))
def test_device_executor_matches_per_hop_reference(seed, n, deg):
    vecs, queries, sp, entries = _small_problem(seed, n)
    stt = build_index(vecs, degree=deg, cache_slots=16, n_max=n, warm=False)
    qj = jnp.asarray(queries)

    def dist_fn(ids):
        return np.asarray(gather_l2(stt.graph.vectors,
                                    jnp.asarray(ids, jnp.int32), qj))

    want = per_hop_reference(stt.graph.nbrs, stt.graph.alive, queries,
                             entries, sp, dist_fn)
    got = frontier_search(stt, qj, jnp.asarray(entries, jnp.int32), sp)
    np.testing.assert_array_equal(np.asarray(got.ids), want)


@settings(max_examples=4, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(60, 140), st.integers(4, 8))
def test_tiered_executor_matches_per_hop_reference(seed, n, deg):
    vecs, queries, sp, entries = _small_problem(seed, n)
    with tempfile.TemporaryDirectory() as td:
        be = build_tiered_backend(vecs, deg, td, host_window=max(16, n // 4))
        hp = C.HostPlacement(be.capacity, 16, vecs.shape[1])
        qj = jnp.asarray(queries)
        _, rows = be.store.peek(np.arange(n))

        def dist_fn(ids):
            B, Cc = ids.shape
            xv = vecs[np.clip(ids, 0, None)]
            d = np.asarray(_batch_sqdist(jnp.asarray(xv), qj))
            return np.where(ids >= 0, d, np.inf).astype(np.float32)

        want = per_hop_reference(rows, be.alive[:be.capacity], queries,
                                 entries, sp, dist_fn)
        got = search_tiered(be, hp, queries, 0, sp, entry_ids=entries)
        np.testing.assert_array_equal(got.ids, want)
        be.close()


def test_tiered_dispatch_count_drops_with_beam():
    """Acceptance: device dispatches per query <= 1 + ceil(hops/beam),
    a ~beam-fold drop from the per-hop loop's one-dispatch-per-hop."""
    rng = np.random.default_rng(0)
    n, deg = 400, 8
    vecs = rng.normal(size=(n, 12)).astype(np.float32)
    queries = rng.normal(size=(8, 12)).astype(np.float32)
    with tempfile.TemporaryDirectory() as td:
        be = build_tiered_backend(vecs, deg, td, host_window=128)
        hp = C.HostPlacement(be.capacity, 16, vecs.shape[1])
        for beam in (1, 4):
            sp = SearchParams(k=5, pool=32, max_iters=32, beam=beam)
            res = search_tiered(be, hp, queries, 0, sp)
            assert res.dispatches <= 1 - (-sp.max_iters // beam)
        be.close()


def test_executor_beam_pool_has_no_duplicates():
    """Round-level dedup: the same id reaching a round from several beam
    slots (or tiers) must occupy at most one pool slot."""
    rng = np.random.default_rng(1)
    n, deg = 300, 8
    vecs = rng.normal(size=(n, 12)).astype(np.float32)
    stt = build_index(vecs, degree=deg, cache_slots=32, n_max=n)
    sp = SearchParams(k=16, pool=32, max_iters=32, beam=4)
    q = jnp.asarray(rng.normal(size=(8, 12)).astype(np.float32))
    res = frontier_search(stt, q, jnp.asarray(
        rng.integers(0, n, (8, sp.pool)), jnp.int32), sp)
    ids = np.asarray(res.ids)
    for row in ids:
        real = row[row >= 0]
        assert len(set(real.tolist())) == len(real)
