"""SLO serving-tier tests: degradation ladder, pressure hysteresis,
weighted-fair draining, deadline admission, shed-as-last-resort — plus
regression tests for the three scheduler/serving bugfixes riding this
change (coalescer shutdown race, `max_batch` overshoot, ServeEngine
straggler EWMA poisoning) and a submit/stop interleaving stress test."""
import threading
import time

import numpy as np
import pytest

from repro.core import slo
from repro.core.engine import (CoalescingScheduler, EngineConfig,
                               SVFusionEngine, _SearchFuture)
from repro.core.search import effective_rerank_depth
from repro.core.types import SearchParams

D = 16


def _fut(rows, tenant=None, deadline=None):
    return _SearchFuture(np.zeros((rows, D), np.float32),
                         tenant=tenant, deadline=deadline)


# -- degradation ladder ---------------------------------------------------

def test_degrade_params_progression():
    sp = SearchParams(k=10, pool=64, max_iters=96, beam=16)
    # level 0: identity
    assert slo.degrade_params(sp, 0, 0) == (sp, 0)
    # level 1: re-rank depth halves from the whole-pool sentinel
    sp1, rr1 = slo.degrade_params(sp, 0, 1)
    assert sp1 == sp and rr1 == 32
    # level 2: beam halves WITH the hop budget (round count constant)
    sp2, rr2 = slo.degrade_params(sp, 0, 2)
    assert rr2 == 32 and sp2.beam == 8 and sp2.max_iters == 48
    # level 3: fused round budget halves again
    sp3, rr3 = slo.degrade_params(sp, 0, 3)
    assert rr3 == 32 and sp3.beam == 8 and sp3.max_iters == 24


def test_degrade_params_floors():
    sp = SearchParams(k=10, pool=16, max_iters=4, beam=4)
    sp3, rr3 = slo.degrade_params(sp, 10, 3)
    assert rr3 == 10                      # floor k
    assert sp3.beam == 4                  # floor 4
    assert sp3.max_iters == sp3.beam      # floor one beam's worth
    # shares the executor's sentinel resolution
    assert effective_rerank_depth(0, 10, 16) == 16
    assert effective_rerank_depth(3, 10, 16) == 10


def test_degrade_params_unknown_stage_raises():
    sp = SearchParams(k=4, pool=16)
    with pytest.raises(ValueError):
        slo.degrade_params(sp, 0, 1, order=("nope",))


# -- latency reservoir / pressure controller ------------------------------

def test_latency_reservoir_ring_and_quantiles():
    r = slo.LatencyReservoir(cap=4)
    assert len(r) == 0 and r.quantile(99) is None
    for x in (1.0, 2.0, 3.0, 4.0, 5.0, 6.0):
        r.add(x)
    assert len(r) == 4                    # newest cap samples survive
    assert r.quantile(0) == 3.0 and r.quantile(100) == 6.0


def test_pressure_controller_hysteresis():
    pol = slo.SLOPolicy(target_p99=0.05, degrade_at=0.5, shed_at=1.0,
                        restore_after=2)
    pc = slo.PressureController(pol)
    assert pc.update(0.9) == 3            # escalation is immediate
    assert pc.update(0.1) == 3            # one calm dispatch is noise
    assert pc.update(0.1) == 2            # restore_after -> one level
    assert pc.update(0.9) == 3            # flap re-escalates instantly
    for _ in range(3 * pol.restore_after):
        pc.update(0.0)
    assert pc.level == 0                  # knobs fully restore when calm


# -- weighted-fair admission ----------------------------------------------

def test_weighted_fair_drain_interleaves_cold_tenant():
    tier = slo.ServingTier(slo.SLOPolicy())
    hot = [_fut(1, tenant="hot") for _ in range(10)]
    for f in hot:
        tier.offer(f)
    cold = _fut(1, tenant="cold")
    tier.offer(cold)
    batch = tier.collect(4, 1e-4, threading.Event())
    # stride scheduling: the lone cold request rides the FIRST dispatch
    # even behind a 10-deep hot backlog
    assert cold in batch and len(batch) == 4


def test_deadline_skip_and_fail():
    tier = slo.ServingTier(slo.SLOPolicy())
    fut = _fut(2, deadline=0.005)
    assert tier.offer(fut)
    time.sleep(0.02)                      # deadline now unmeetable
    batch = tier.collect(8, 1e-4, threading.Event())
    assert batch == []
    with pytest.raises(slo.DeadlineMissError):
        fut.result(timeout=1.0)
    st = tier.stats()
    assert st["deadline_misses"] == 1
    assert st["tenants"][slo.DEFAULT_TENANT]["deadline_misses"] == 1


def test_shed_only_at_max_degradation():
    pol = slo.SLOPolicy(target_p99=0.01, shed_at=1.0)
    tier = slo.ServingTier(pol)
    tier.rows_per_s = 100.0               # modeled service rate
    admitted = [_fut(20, tenant="t") for _ in range(3)]
    for f in admitted:
        # modeled wait grows far past shed_at x target, but degradation
        # has headroom (level 0) -> every request is still admitted
        assert tier.offer(f)
    assert tier.shed_total == 0
    tier.controller.level = pol.n_levels  # degradation maxed out
    shed = _fut(20, tenant="t")
    assert not tier.offer(shed)           # now, and only now, shed
    with pytest.raises(slo.LoadShedError):
        shed.result(timeout=1.0)
    assert tier.shed_total == 1
    assert tier.stats()["tenants"]["t"]["shed"] == 1


def test_disabled_policy_never_sheds_or_pressures():
    tier = slo.ServingTier(slo.SLOPolicy(target_p99=0.0))
    tier.rows_per_s = 1.0
    tier.controller.level = 3
    f = _fut(50)
    assert tier.offer(f)                  # no shedding when disabled
    tier.complete([], 50, 0.5, ok=True)
    assert tier.pressure == 0.0


# -- bugfix regressions ---------------------------------------------------

def test_overshoot_peek_dont_admit():
    """Regression: the dispatcher admitted one more request after the
    row cap was reached, so a 5+5+5-row arrival at max_batch=8 dispatched
    10 rows and jumped the pow2 padding bucket. The head that would cross
    the cap must stay queued for the next dispatch."""
    tier = slo.ServingTier(slo.SLOPolicy())
    futs = [_fut(5) for _ in range(3)]
    for f in futs:
        tier.offer(f)
    stop = threading.Event()
    sizes = [sum(len(f.queries) for f in tier.collect(8, 1e-4, stop))
             for _ in range(3)]
    assert sizes == [5, 5, 5]             # legacy code produced [10, 5]
    assert tier.overshoot_avoided >= 2
    # a single oversized request still dispatches alone (no livelock)
    big = _fut(16)
    tier.offer(big)
    assert tier.collect(8, 1e-4, stop) == [big]


def _ok_search(q, degrade=0):
    k = 4
    return (np.zeros((len(q), k), np.int64),
            np.zeros((len(q), k), np.float32))


def test_stop_drains_queued_futures_and_rejects_new():
    gate = threading.Event()

    def blocked(q, degrade=0):
        gate.wait(5.0)
        return _ok_search(q)

    co = CoalescingScheduler(blocked, max_batch=8, max_window=1e-4)
    f1 = co.submit(np.zeros((8, D), np.float32))   # fills the batch ->
    time.sleep(0.05)                               # dispatched, stuck
    f2 = co.submit(np.zeros((2, D), np.float32))   # still queued
    with pytest.raises(RuntimeError, match="did not exit"):
        co.stop(join_timeout=0.2)                  # loud, not silent
    with pytest.raises(RuntimeError):
        f2.result(timeout=1.0)                     # drained, not hung
    with pytest.raises(RuntimeError):
        co.submit(np.zeros((1, D), np.float32))    # closed to new work
    gate.set()                                     # release the thread
    f1.result(timeout=5.0)                         # in-flight completes


def test_coalescer_submit_stop_stress():
    """Regression for the shutdown race: stop() used to drain the queue
    while a timed-out-but-alive dispatcher kept popping it, so a future
    could complete twice or never. Hammer submit/stop interleavings and
    assert every future resolves (result or error) within its timeout."""
    for trial in range(4):
        def slow(q, degrade=0):
            time.sleep(0.002)
            return _ok_search(q)

        co = CoalescingScheduler(slow, max_batch=16, max_window=2e-4)
        futs, lock = [], threading.Lock()

        def client():
            for _ in range(8):
                try:
                    f = co.submit(np.zeros((2, D), np.float32))
                except RuntimeError:
                    return                # stopped under us: fine
                with lock:
                    futs.append(f)

        ths = [threading.Thread(target=client) for _ in range(6)]
        for t in ths:
            t.start()
        time.sleep(0.003 * (trial + 1))   # vary the interleaving
        co.stop()
        for t in ths:
            t.join()
        for f in futs:
            try:
                ids, _ = f.result(timeout=10.0)   # TimeoutError = hang
                assert len(ids) == 2
            except RuntimeError:
                pass                      # drained at shutdown: fine


def test_serve_straggler_consecutive_detection():
    """Regression: tick() folded a straggler's dt into the EWMA before
    the check, inflating the threshold so the second of two consecutive
    stragglers went undetected. Both must be flagged and neither may
    move the EWMA."""
    from repro.serve.engine import ServeEngine
    eng = object.__new__(ServeEngine)
    eng.straggler_factor = 8.0
    eng.tick_ewma = None
    eng.stragglers = 0
    assert not eng._observe_tick(0.01)    # seeds the EWMA
    assert eng._observe_tick(0.2)         # straggler #1
    assert eng._observe_tick(0.2)         # straggler #2 (was invisible:
    #                                       poisoned EWMA 0.029 * 8 > 0.2)
    assert eng.stragglers == 2
    assert eng.tick_ewma == pytest.approx(0.01)


# -- engine-level integration --------------------------------------------

def test_engine_slo_stats_and_degraded_exec(tmp_path):
    rng = np.random.default_rng(11)
    n = 300
    vecs = rng.normal(size=(n, D)).astype(np.float32)
    eng = SVFusionEngine(vecs, EngineConfig(
        degree=8, cache_slots=64, capacity=2 * n,
        disk_path=str(tmp_path / "t"), disk_capacity=2 * n,
        host_window=n // 4, search=SearchParams(k=4, pool=48, max_iters=96),
        seed=0, slo_target_p99=30.0))     # huge target: active, never shed
    try:
        ids, _ = eng.search(vecs[:4], tenant="alice")
        assert ids.shape == (4, 4)
        eng.search(vecs[4:8], tenant="bob", deadline=30.0)
        st = eng.stats()
        assert st["coalesce_overshoot_avoided"] == 0
        assert st["degraded_dispatches"] == 0
        s = st["slo"]
        assert s["target_p99_ms"] == pytest.approx(30e3)
        assert set(s["tenants"]) == {"alice", "bob"}
        assert s["tenants"]["alice"]["completed"] == 1
        assert s["tenants"]["alice"]["p99_ms"] is not None
        # degraded executor paths return well-formed results (level > 0
        # reaches search_tiered via SearchParams/rerank overrides)
        for lvl in (1, 2, 3):
            ids, dists = eng._search_exec(vecs[:2], update_cache=False,
                                          degrade=lvl)
            assert ids.shape == (2, 4) and np.all(ids >= 0)
    finally:
        eng.close()
