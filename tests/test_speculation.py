"""Speculative pipeline + cross-query coalescer tests.

The speculative stage must be *bitwise transparent*: staged rows/vectors
are the same values the demand path would fetch, so ``search_tiered``
results cannot depend on prediction quality — pinned here under forced
0% and forced 100% misprediction, plus an interleaved insert/delete run
showing the write-epoch flush keeps MVCC reads coherent while rows are
staged. The coalescing scheduler must demultiplex exactly (every request
gets its own rows back) and its adaptive window must shrink under light
load."""
import tempfile
import threading

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:   # no network route: replay fixed seeded examples
    from _hypothesis_shim import given, settings, st

from repro.core import cache as C
from repro.core.build import build_tiered_backend
from repro.core.engine import (CoalescingScheduler, EngineConfig,
                               SVFusionEngine)
from repro.core.search import predict_frontier, search_tiered
from repro.core.types import SearchParams

D = 16


def _predict_all(ids, valid, f_lam, width, d_host=None):
    """Forced 0% misprediction: stage every valid candidate, so the real
    frontier is always a subset of the staged set."""
    return np.where(valid, ids, -1)


def _predict_none(ids, valid, f_lam, width, d_host=None):
    """Forced 100% misprediction: never stage anything."""
    return np.full((ids.shape[0], 1), -1, np.int64)


def _make(tmp, n, deg, seed=0):
    rng = np.random.default_rng(seed)
    vecs = rng.normal(size=(n, D)).astype(np.float32)
    be = build_tiered_backend(vecs, deg, tmp, disk_capacity=2 * n,
                              host_window=max(32, n // 4))
    hp = C.HostPlacement(be.capacity, 64, D)
    return vecs, be, hp


@settings(max_examples=4, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(80, 240), st.integers(4, 8))
def test_speculation_bit_identical_under_forced_misprediction(seed, n, deg):
    """Property: speculative and non-speculative search return
    bit-identical pools whatever the predictor does — always right
    (superset staging), always wrong (empty staging), or the real F_λ /
    distance-ranked guesses."""
    with tempfile.TemporaryDirectory() as td:
        vecs, be, hp = _make(td, n, deg, seed % 1000)
        rng = np.random.default_rng(seed)
        q = rng.normal(size=(6, D)).astype(np.float32)
        sp = SearchParams(k=8, pool=24, max_iters=24, beam=4)
        entries = rng.integers(0, n, (6, sp.pool))
        base = search_tiered(be, hp, q, 0, sp, entry_ids=entries,
                             speculate=False)
        variants = {
            "forced-hit": dict(spec_predict=_predict_all),
            "forced-miss": dict(spec_predict=_predict_none),
            "flam": dict(spec_rank="flam"),
            "dist": dict(spec_rank="dist"),
        }
        for tag, kw in variants.items():
            got = search_tiered(be, hp, q, 0, sp, entry_ids=entries,
                                speculate=True, **kw)
            np.testing.assert_array_equal(base.ids, got.ids, err_msg=tag)
            np.testing.assert_array_equal(base.dists, got.dists,
                                          err_msg=tag)
            np.testing.assert_array_equal(base.acc_ids, got.acc_ids,
                                          err_msg=tag)
            np.testing.assert_array_equal(base.acc_hit, got.acc_hit,
                                          err_msg=tag)
        be.close()


def test_speculation_hit_rate_extremes(tmp_path):
    """The hit-rate accounting matches the forcing: superset staging
    scores all hits, empty staging scores all misses. Single query: with
    B > 1 an id demand-fetched for one query legitimately serves another
    query's later round from the memo, which is cross-query reuse, not
    prediction."""
    vecs, be, hp = _make(str(tmp_path), 400, 8)
    rng = np.random.default_rng(1)
    q = rng.normal(size=(1, D)).astype(np.float32)
    sp = SearchParams(k=8, pool=32, max_iters=32, beam=4)
    always = search_tiered(be, hp, q, 0, sp, spec_predict=_predict_all)
    never = search_tiered(be, hp, q, 0, sp, spec_predict=_predict_none)
    off = search_tiered(be, hp, q, 0, sp, speculate=False)
    assert always.spec_hit_rate == 1.0
    assert never.spec_hit_rate == 0.0
    assert never.spec_misses > 0
    assert off.spec_hits == 0 and off.spec_misses == 0
    be.close()


def test_speculation_epoch_flush_on_write(tmp_path):
    """A write between staging and use flushes the memo: the staged row
    is dropped, not served stale (the correctness core of MVCC-while-
    staging)."""
    vecs, be, hp = _make(str(tmp_path), 300, 8)
    from repro.core.search import _SpecPipeline
    f_lam = hp.scores(be.e_in)
    view = hp.view
    spec = _SpecPipeline(be, view.h2d, view.vectors, f_lam)
    ids = np.arange(10)
    spec.stage(ids)
    assert (spec.rows.loc[ids] >= 0).all()
    new_row = np.full((1, be.degree), 7, np.int32)
    be.store.write(np.array([3]), nbrs=new_row)     # concurrent mutation
    spec.validate()
    assert (spec.rows.loc[ids] == -1).all()         # memo flushed wholesale
    got = spec.rows_for(np.array([3]))
    np.testing.assert_array_equal(got[0], new_row[0])   # fresh, not stale
    be.close()


def test_speculation_consistent_under_interleaved_updates(tmp_path):
    """Interleaved insert/delete while speculation stages rows: searches
    through the engine stay consistent — acknowledged inserts are
    findable, deleted ids never surface, and the store's residency stays
    exact (the write-epoch flush is what makes this safe)."""
    rng = np.random.default_rng(3)
    n = 600
    vecs = rng.normal(size=(n, D)).astype(np.float32)
    eng = SVFusionEngine(vecs, EngineConfig(
        degree=8, cache_slots=64, capacity=4 * n,
        disk_path=str(tmp_path / "t"), disk_capacity=4 * n,
        host_window=n // 4, search=SearchParams(k=8, pool=48, max_iters=96),
        seed=0, consolidate_threshold=2.0))
    try:
        stop = threading.Event()
        errors = []

        def churn():
            r = np.random.default_rng(7)
            try:
                while not stop.is_set():
                    ids = eng.insert(
                        r.normal(size=(8, D)).astype(np.float32))
                    eng.delete(ids[:4])
            except Exception as e:  # pragma: no cover
                errors.append(e)

        th = threading.Thread(target=churn)
        th.start()
        raw_hits = []
        try:
            for i in range(15):
                newv = rng.normal(size=(4, D)).astype(np.float32)
                ids = eng.insert(newv)
                found, _ = eng.search(newv)
                # read-after-write quality is aggregated below: under
                # churn a single probe can miss without any write loss
                raw_hits.append(float((found[:, 0] == ids).mean()))
                eng.delete(ids)
                found2, _ = eng.search(newv)
                # deletions are exact: a deleted id must NEVER surface
                assert not np.isin(ids, found2).any()
        finally:
            stop.set()
            th.join()
        assert not errors, errors[0]
        assert float(np.mean(raw_hits)) > 0.7, raw_hits
        assert eng.stats()["spec_hits"] + eng.stats()["spec_misses"] > 0
        store = eng.state.tiered.store
        occ = store.slot_id >= 0
        np.testing.assert_array_equal(
            store.loc[store.slot_id[occ]], np.where(occ)[0])
    finally:
        eng.close()


def test_predict_frontier_ranking():
    """The F_λ probe returns the hottest valid candidates; host distances
    override it when provided (entry stage)."""
    ids = np.array([[5, 9, 2, 7]])
    valid = np.array([[True, True, False, True]])
    f_lam = np.zeros(10, np.float32)
    f_lam[[5, 9, 7]] = [3.0, 1.0, 2.0]
    got = predict_frontier(ids, valid, f_lam, 2)
    assert got.tolist() == [[5, 7]]
    d_host = np.array([[0.5, 0.1, 0.0, 0.9]])
    got = predict_frontier(ids, valid, f_lam, 2, d_host=d_host)
    assert got.tolist() == [[9, 5]]
    # no valid candidate -> all -1, never a bogus id
    got = predict_frontier(ids, np.zeros_like(valid), f_lam, 2)
    assert (got == -1).all()


# ---------------------------------------------------------------------------
# cross-query coalescing scheduler
# ---------------------------------------------------------------------------

def test_coalescer_demux_exact():
    """Concurrent requests of different sizes merge into shared dispatches
    and every request gets exactly its own rows back."""
    calls = []

    def search_fn(qs):
        calls.append(len(qs))
        return qs[:, :1].astype(np.int32), qs[:, :1]

    co = CoalescingScheduler(search_fn, max_batch=64, max_window=5e-3)
    rng = np.random.default_rng(0)
    reqs = [rng.normal(size=(b, 4)).astype(np.float32)
            for b in (1, 3, 2, 5, 4, 1, 7, 2)]
    futs = [co.submit(q) for q in reqs]
    for q, f in zip(reqs, futs):
        ids, dists = f.result(timeout=10)
        assert len(ids) == len(q)
        np.testing.assert_allclose(dists, q[:, :1])
        assert f.latency > 0
    assert co.requests == len(reqs)
    assert co.queries == sum(len(q) for q in reqs)
    assert co.dispatches <= len(reqs)        # at least some merging
    co.stop()


def test_coalescer_adaptive_window_shrinks_when_idle():
    """Uncoalesced dispatches shrink the window toward the floor so a
    lone caller's p50 converges to the direct-call latency; merged ones
    grow it (bounded)."""
    co = CoalescingScheduler(lambda qs: (qs, qs), max_batch=8,
                             max_window=2e-3, min_window=5e-5)
    co.window = 2e-3
    q = np.zeros((1, 4), np.float32)
    for _ in range(12):
        co.search(q)                         # serial -> never coalesces
    assert co.window == co.min_window
    assert co.coalesced == 0
    co.stop()


def test_coalescer_propagates_errors():
    def boom(qs):
        raise RuntimeError("executor failed")

    co = CoalescingScheduler(boom)
    fut = co.submit(np.zeros((2, 4), np.float32))
    with pytest.raises(RuntimeError, match="executor failed"):
        fut.result(timeout=10)
    co.stop()


def test_engine_coalesces_across_threads(tmp_path):
    """Engine-level: N submitter threads share executor dispatches (mean
    coalesced batch > one request's rows) and results are per-request
    correct (each query's own nearest neighbor comes back first)."""
    rng = np.random.default_rng(5)
    n = 500
    vecs = rng.normal(size=(n, D)).astype(np.float32)
    eng = SVFusionEngine(vecs, EngineConfig(
        degree=8, cache_slots=64, capacity=2 * n,
        disk_path=str(tmp_path / "t"), disk_capacity=2 * n,
        host_window=n // 4, search=SearchParams(k=4, pool=48, max_iters=96),
        seed=0, coalesce_window=5e-3))
    try:
        eng.search(vecs[:8], update_cache=False)     # warm the pipeline
        hits = []
        errors = []

        def client(lo):
            try:
                for i in range(6):
                    sel = (lo + 7 * i) % n
                    ids, _ = eng.search(vecs[sel:sel + 4])
                    hits.append(float((ids[:, 0]
                                       == np.arange(sel, sel + 4)).mean()))
            except Exception as e:  # pragma: no cover
                errors.append(e)

        ths = [threading.Thread(target=client, args=(s,))
               for s in (0, 100, 200, 300)]
        for t in ths:
            t.start()
        for t in ths:
            t.join()
        assert not errors, errors[0]
        assert np.mean(hits) > 0.9           # demux returned the right rows
        st = eng.stats()
        assert st["coalesce_requests"] >= 24
        assert st["coalesce_batch_mean"] > 4.0   # > one request's rows
    finally:
        eng.close()
