"""Durability subsystem tests (core/wal.py): WAL framing, torn-tail
truncation, group commit, snapshot/manifest contracts, degraded mode,
fsync'd flush, prefetcher shutdown — and the subprocess crash matrix:
kill -9 (``os._exit(137)`` via ``tests/faultinject.py``) at every named
crash point, reopen, and bit-compare the recovered state against an
uninterrupted run of the durable record prefix.

A representative slice of the matrix runs in tier-1; set
``SVF_DURABILITY_FULL=1`` (``make verify-durability``) for the full
crash-point x workload grid including the PQ variants.
"""
import dataclasses
import os
import subprocess
import sys

import numpy as np
import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "src"))
sys.path.insert(0, os.path.join(ROOT, "tests"))

import faultinject                                         # noqa: E402
from repro.core import wal as walmod                       # noqa: E402

DRIVER = os.path.join(ROOT, "tests", "faultinject.py")


# ---------------------------------------------------------------------------
# WAL framing / segment mechanics (no engine)
# ---------------------------------------------------------------------------

def test_wal_frame_roundtrip(tmp_path):
    p = str(tmp_path / "w.log")
    w = walmod.WriteAheadLog(p, group_commit=1)
    payload = {"ids": np.arange(4), "note": "x"}
    assert w.append(walmod.REC_DELETE, payload) == 1
    assert w.append(walmod.REC_INSERT, {"ids": np.arange(2)}) == 2
    w.close()
    recs, valid = walmod.read_records(p)
    assert [(r[0], r[1]) for r in recs] == [(walmod.REC_DELETE, 1),
                                            (walmod.REC_INSERT, 2)]
    assert np.array_equal(recs[0][2]["ids"], np.arange(4))
    assert valid == os.path.getsize(p)


def test_wal_torn_tail_truncated(tmp_path):
    p = tmp_path / "w.log"
    w = walmod.WriteAheadLog(str(p), group_commit=1)
    w.append(walmod.REC_DELETE, {"ids": np.arange(3)})
    w.append(walmod.REC_DELETE, {"ids": np.arange(5)})
    w.close()
    good = p.read_bytes()

    # a frame torn mid-body (crashed group-commit batch)
    torn = walmod._frame(walmod.REC_DELETE, 3, {"ids": np.arange(9)})[:-4]
    p.write_bytes(good + torn)
    recs, valid = walmod.read_records(str(p))
    assert [r[1] for r in recs] == [1, 2] and valid == len(good)

    # raw garbage (bad magic)
    p.write_bytes(good + b"garbage!")
    recs, valid = walmod.read_records(str(p))
    assert [r[1] for r in recs] == [1, 2] and valid == len(good)


def test_wal_corrupt_record_stops_scan(tmp_path):
    p = tmp_path / "w.log"
    f1 = walmod._frame(walmod.REC_DELETE, 1, {"a": 1})
    f2 = walmod._frame(walmod.REC_DELETE, 2, {"b": 2})
    f3 = walmod._frame(walmod.REC_DELETE, 3, {"c": 3})
    bad = bytearray(f1 + f2 + f3)
    bad[len(f1) + walmod._HDR.size] ^= 0x5A        # flip a byte in f2's body
    p.write_bytes(bytes(bad))
    recs, valid = walmod.read_records(str(p))
    # the scan must stop AT the corrupt record, not skip over it: ops are
    # causally ordered, so replaying f3 without f2 would be wrong
    assert [r[1] for r in recs] == [1] and valid == len(f1)


def test_wal_group_commit_batches(tmp_path):
    w = walmod.WriteAheadLog(str(tmp_path / "w.log"), group_commit=3)
    w.append(walmod.REC_DELETE, {"i": 0})
    w.append(walmod.REC_DELETE, {"i": 1})
    assert w.appended == 2 and w.synced == 0       # fsync deferred
    w.append(walmod.REC_DELETE, {"i": 2})
    assert w.synced == 3                           # batch boundary fsyncs
    w.append(walmod.REC_DELETE, {"i": 3})
    assert w.synced == 3
    w.sync()
    assert w.synced == 4
    w.close()
    assert w.last_seq == 4


def test_wal_poisoned_after_write_error(tmp_path):
    w = walmod.WriteAheadLog(str(tmp_path / "w.log"), group_commit=1)
    w._f.close()                                   # simulate device failure
    with pytest.raises(walmod.WALWriteError):
        w.append(walmod.REC_DELETE, {"i": 0})
    assert w.failed
    with pytest.raises(walmod.WALWriteError):      # stays poisoned
        w.append(walmod.REC_DELETE, {"i": 1})


# ---------------------------------------------------------------------------
# Engine-level durability (in-process)
# ---------------------------------------------------------------------------

def _engine(tmp_path, pq=False, **over):
    from repro.core.engine import SVFusionEngine
    cfg = faultinject.make_config(str(tmp_path / "store"), pq=pq)
    cfg = dataclasses.replace(cfg, **over)
    data = faultinject.dataset()
    return SVFusionEngine(data[:faultinject.N0], cfg), cfg, data


def test_clean_close_reopen_zero_replay_parity(tmp_path):
    from repro.core.engine import SVFusionEngine
    from repro.core.search import search_tiered
    from repro.core.types import SearchParams
    eng, cfg, data = _engine(tmp_path)
    eng.insert(data[256:320])
    eng.delete(np.arange(10, 40))
    q = np.random.default_rng(1).normal(size=(6, faultinject.D)) \
        .astype(np.float32)
    sp = SearchParams(k=8, pool=32, max_iters=32)
    r1 = search_tiered(eng._backend, eng._placement, q, 99, sp,
                       speculate=False)
    nbr1 = eng._backend.store.peek_rows(np.arange(eng._backend.n))
    eng.close()

    eng2 = SVFusionEngine(None, cfg)
    st = eng2.stats()
    assert st["recovered_replayed"] == 0           # close() checkpointed
    assert st["degraded"] is False
    r2 = search_tiered(eng2._backend, eng2._placement, q, 99, sp,
                       speculate=False)
    assert np.array_equal(np.asarray(r1.ids), np.asarray(r2.ids))
    assert np.array_equal(np.asarray(r1.dists), np.asarray(r2.dists))
    assert np.array_equal(nbr1,
                          eng2._backend.store.peek_rows(
                              np.arange(eng2._backend.n)))
    eng2.close()


def test_reopen_without_close_replays_wal(tmp_path):
    """Abandoning the engine (no close, no checkpoint) must still recover
    every op: the WAL is unbuffered, so appended records are visible to a
    reader even while the writer lives."""
    from repro.core.engine import SVFusionEngine
    eng, cfg, data = _engine(tmp_path, wal_group_commit=1)
    eng.insert(data[256:320])
    eng.delete(np.arange(5, 25))
    n1 = int(eng._backend.n)
    alive1 = eng._backend.alive[:n1].copy()
    e_in1 = eng._backend.e_in.copy()
    # no close: simulate the process simply going away
    eng2 = SVFusionEngine(None, cfg)
    st = eng2.stats()
    assert st["recovered_replayed"] == 2
    assert int(eng2._backend.n) == n1
    assert np.array_equal(alive1, eng2._backend.alive[:n1])
    assert np.array_equal(e_in1, eng2._backend.e_in)
    eng2.close()


def test_manifest_contract_errors(tmp_path):
    from repro.core.engine import SVFusionEngine
    eng, cfg, data = _engine(tmp_path)
    eng.close()
    # a published index refuses fresh init vectors (would shadow it)
    with pytest.raises(ValueError, match="recover"):
        SVFusionEngine(data[:faultinject.N0], cfg)
    # ...and refuses to open with the WAL disabled (silent divergence)
    with pytest.raises(ValueError, match="wal"):
        SVFusionEngine(None, dataclasses.replace(cfg, wal_enabled=False))
    # an empty directory has nothing to recover
    cfg3 = dataclasses.replace(cfg, disk_path=str(tmp_path / "empty"))
    with pytest.raises(ValueError, match="recover"):
        SVFusionEngine(None, cfg3)


def test_degraded_read_only_on_wal_failure(tmp_path):
    from repro.core.engine import ReadOnlyEngineError
    eng, cfg, data = _engine(tmp_path)
    eng.insert(data[256:288])
    eng._wal._f.close()                            # WAL device dies
    with pytest.raises(ReadOnlyEngineError):
        eng.insert(data[288:320])
    st = eng.stats()
    assert st["degraded"]
    # the failed op was NOT applied (WAL-before-write)
    assert int(eng._backend.n) == 288
    # reads keep working
    ids, _ = eng.search(data[:4])
    assert np.asarray(ids).shape[0] == 4
    with pytest.raises(ReadOnlyEngineError):
        eng.delete(np.arange(4))
    eng.close()                                    # must not raise


def test_pre_attribute_manifest_recovers_with_empty_store(tmp_path):
    """Backward compat: an index published BEFORE the attribute subsystem
    existed (manifest without an "attrs" key) must recover cleanly — and
    reopening it with ``cfg.attributes`` set attaches an EMPTY store
    (schema defaults for every pre-existing id) rather than failing."""
    import dataclasses as dc

    from repro.core.engine import SVFusionEngine
    from repro.core.filters import AttributeSchema, FilterSpec
    eng, cfg, data = _engine(tmp_path)          # no attributes configured
    eng.insert(data[256:288])
    eng.close()

    # plain reopen: no attrs in the manifest, no store attached
    eng2 = SVFusionEngine(None, cfg)
    assert eng2._backend.attrs is None
    eng2.close()

    # reopen WITH a schema: empty store attaches, filtered search runs
    # against all-default columns (tag 0 everywhere)
    schema = AttributeSchema(tag_fields=("cat",), num_fields=("score",))
    eng3 = SVFusionEngine(None, dc.replace(cfg, attributes=schema))
    a = eng3._backend.attrs
    assert a is not None and a.written == 0
    assert (a.tags[:eng3._backend.n] == 0).all()
    ids, _ = eng3.search(data[:2], filter=FilterSpec(tags={"cat": {0}}))
    assert (np.asarray(ids) >= 0).any()
    ids, _ = eng3.search(data[:2], filter=FilterSpec(tags={"cat": {3}}))
    assert (np.asarray(ids) == -1).all()
    # new inserts carry attributes; a checkpoint upgrades the manifest
    nid = eng3.insert(data[288:292], attributes={"cat": np.full(4, 3),
                                                 "score": np.ones(4)})
    eng3.checkpoint()
    eng3.close()
    eng4 = SVFusionEngine(None, dc.replace(cfg, attributes=schema))
    assert eng4._backend.attrs is not None
    ids, _ = eng4.search(data[288:290], filter=FilterSpec(tags={"cat": {3}}))
    live = np.asarray(ids)
    assert set(live[live >= 0].tolist()) <= set(np.asarray(nid).tolist())
    eng4.close()


def test_checkpoint_rotates_segment(tmp_path):
    eng, cfg, data = _engine(tmp_path)
    store = tmp_path / "store"
    eng.insert(data[256:288])
    assert eng.stats()["wal_records"] == 1
    epoch = eng.checkpoint()
    assert epoch == 1
    man = walmod.load_manifest(str(store))
    assert man["epoch"] == 1 and man["op_seq"] == 1
    # rotation continues the op_seq numbering and prunes stale epochs
    eng.insert(data[288:320])
    assert eng.stats()["wal_last_seq"] == 2
    names = set(os.listdir(store))
    assert "wal-00000001.log" in names and "wal-00000000.log" not in names
    assert "snapshot-00000000.npz" not in names
    eng.close()


def test_disk_flush_fsyncs_backing_files(tmp_path, monkeypatch):
    eng, cfg, data = _engine(tmp_path)
    calls = []
    real = os.fsync
    monkeypatch.setattr(os, "fsync", lambda fd: (calls.append(fd),
                                                 real(fd))[1])
    eng._backend.store.disk.flush()
    assert len(calls) >= 2                         # vectors.npy + nbrs.npy
    monkeypatch.undo()
    eng.close()


def test_prefetcher_stop_is_terminal(tmp_path):
    eng, cfg, data = _engine(tmp_path, prefetch=True)
    store = eng._backend.store
    assert store._th is not None
    store.stop()
    assert store._th is None
    store.prefetch(np.arange(8))                   # no-op, no crash
    store.start_prefetcher()                       # refuses to restart
    assert store._th is None
    eng.close()                                    # second stop() is fine


# ---------------------------------------------------------------------------
# Subprocess crash matrix: kill -9 -> reopen -> bit-parity vs clean run
# ---------------------------------------------------------------------------

TIER1_COMBOS = [
    ("insert_heavy", "post_wal_append", 4),
    ("insert_heavy", "mid_memmap_write", 1),
    ("insert_heavy", "pre_manifest_rename", 3),    # crash inside checkpoint
    ("delete_heavy", "post_wal_append", 5),
    ("consolidation", "mid_consolidation_merge", 3),
]

FULL_COMBOS = [
    ("insert_heavy", "post_wal_append", 0),
    ("insert_heavy", "post_wal_append", 6),
    ("insert_heavy", "mid_memmap_write", 4),
    ("insert_heavy", "mid_memmap_write", 6),
    ("delete_heavy", "post_wal_append", 1),
    ("delete_heavy", "post_wal_append", 6),
    ("delete_heavy", "mid_memmap_write", 2),
    ("delete_heavy", "pre_manifest_rename", 4),
    ("consolidation", "post_wal_append", 3),
    ("consolidation", "mid_memmap_write", 4),
    ("insert_heavy_pq", "post_wal_append", 4),
    ("insert_heavy_pq", "mid_memmap_write", 1),
    ("insert_heavy_pq", "pre_manifest_rename", 3),
    ("consolidation_pq", "mid_consolidation_merge", 3),
    ("consolidation_pq", "post_wal_append", 1),
    # attribute-bearing workload: extended INSERT payload replay +
    # attribute-column snapshot recovery + filtered-search parity
    ("insert_heavy_attrs", "post_wal_append", 4),
    ("insert_heavy_attrs", "mid_memmap_write", 1),
]

_CLEAN_DIGESTS = {}


def _run_driver(args, timeout=300):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + os.pathsep \
        + env.get("PYTHONPATH", "")
    return subprocess.run([sys.executable, DRIVER] + [str(a) for a in args],
                          env=env, capture_output=True, text=True,
                          timeout=timeout)


def _clean_digest(tmp_path_factory, workload, records):
    """Uninterrupted-run digests depend only on (workload, record-prefix
    length) — memoized so the matrix doesn't rebuild identical baselines."""
    key = (workload, records)
    if key not in _CLEAN_DIGESTS:
        d = tmp_path_factory.mktemp(f"clean-{workload}-{records}")
        out = d / "digest.npz"
        r = _run_driver([workload, "clean", "--dir", d / "store",
                         "--out", out, "--records", records])
        assert r.returncode == 0, f"clean driver failed:\n{r.stderr}"
        _CLEAN_DIGESTS[key] = str(out)
    return np.load(_CLEAN_DIGESTS[key])


def _crash_reopen_parity(tmp_path, tmp_path_factory, workload, point, op):
    ops = faultinject.WORKLOADS[workload]
    store = tmp_path / "store"

    r = _run_driver([workload, "crash", "--dir", store,
                     "--crash-point", point, "--crash-op", op])
    assert r.returncode == faultinject.CRASH_EXIT, \
        f"expected kill at {point}, got rc={r.returncode}:\n{r.stderr}"

    out = tmp_path / "reopen.npz"
    r = _run_driver([workload, "reopen", "--dir", store, "--out", out])
    assert r.returncode == 0, f"recovery failed:\n{r.stderr}"
    dig = np.load(out)

    k = int(dig["last_seq"])
    assert k == faultinject.expected_records(ops, point, op)

    clean = _clean_digest(tmp_path_factory, workload, k)
    assert set(dig.files) == set(clean.files)
    for key in clean.files:
        assert np.array_equal(dig[key], clean[key]), \
            f"{key} diverged after {point}@op{op} ({workload})"


@pytest.mark.parametrize("workload,point,op", TIER1_COMBOS)
def test_crash_recovery_parity(tmp_path, tmp_path_factory,
                               workload, point, op):
    _crash_reopen_parity(tmp_path, tmp_path_factory, workload, point, op)


@pytest.mark.skipif(not os.environ.get("SVF_DURABILITY_FULL"),
                    reason="full crash matrix: set SVF_DURABILITY_FULL=1 "
                           "(make verify-durability)")
@pytest.mark.parametrize("workload,point,op", FULL_COMBOS)
def test_crash_recovery_parity_full(tmp_path, tmp_path_factory,
                                    workload, point, op):
    _crash_reopen_parity(tmp_path, tmp_path_factory, workload, point, op)
