"""Three-tier (GPU-CPU-disk) path tests: cascading lookup through the
serving engine, MVCC-snapshotted tiered consolidation, TieredStore
thread-safety, WAVP-shared demotion order, and the bandwidth-tier dtype
regression."""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cache as C
from repro.core import mvcc
from repro.core import update as U
from repro.core.build import build_graph, build_index
from repro.core.engine import EngineConfig, SVFusionEngine
from repro.core.search import brute_force_topk, recall_at_k, search_batch
from repro.core.tiers import DiskTier, TieredStore
from repro.core.types import SearchParams

N, D, R = 3000, 24, 16


@pytest.fixture(scope="module")
def dataset():
    rng = np.random.default_rng(0)
    return rng.normal(size=(N, D)).astype(np.float32)


def make_engine(tmp_path, dataset, host_window=700, **kw):
    """Disk-backed engine whose dataset is ≥4x the host window."""
    cfg = EngineConfig(
        degree=R, cache_slots=256, capacity=8192,
        disk_path=str(tmp_path / "tier"), disk_capacity=8192,
        host_window=host_window,
        search=SearchParams(k=10, pool=64, max_iters=96), **kw)
    return SVFusionEngine(dataset, cfg)


# ---------------------------------------------------------------------------
# engine end-to-end over the disk tier
# ---------------------------------------------------------------------------

def test_tiered_engine_search_recall(tmp_path, dataset):
    eng = make_engine(tmp_path, dataset)
    try:
        assert N >= 4 * eng.cfg.host_window   # larger-than-window dataset
        rng = np.random.default_rng(1)
        q = rng.normal(size=(32, D)).astype(np.float32)
        ids, dists = eng.search(q)
        g = build_graph(dataset, R)
        truth, _ = brute_force_topk(g, jnp.asarray(q), 10)
        assert float(recall_at_k(jnp.asarray(ids), truth)) > 0.8
        # distances ascending, per-tier accounting alive
        assert (np.diff(dists, axis=1) >= -1e-5).all()
        st = eng.stats()
        assert st["disk_reads"] > 0 and st["host_hits"] > 0
        assert st["accesses"] == st["hits"] + st["misses"]
        # speculative-pipeline + coalescer accounting surfaces in stats()
        assert st["spec_hits"] + st["spec_misses"] > 0
        assert 0.0 <= st["spec_hit_rate"] <= 1.0
        assert st["coalesce_dispatches"] >= 1
        assert st["coalesce_batch_mean"] >= 1.0
    finally:
        eng.close()


def test_tiered_engine_insert_delete_consolidate(tmp_path, dataset):
    eng = make_engine(tmp_path, dataset)
    try:
        rng = np.random.default_rng(2)
        newv = rng.normal(size=(48, D)).astype(np.float32)
        ids = eng.insert(newv)
        assert int(eng.stats()["n"]) == N + 48
        found, _ = eng.search(newv)
        assert float((found[:, 0] == ids).mean()) > 0.9  # read-after-write
        # delete the new rows; they must vanish from results
        eng.delete(ids)
        found2, _ = eng.search(newv)
        assert not np.isin(ids, found2).any()
        # streaming consolidation scrubs dead edges on disk
        eng.consolidate_async(wait=True)
        be = eng.state.tiered
        _, rows = be.store.peek(np.arange(be.n))
        dead_edges = (rows >= 0) & ~be.alive[np.clip(rows, 0, None)]
        assert dead_edges.sum() == 0
        # e_in rebuilt consistently with the on-disk rows
        e_in = np.zeros((be.capacity,), np.int32)
        np.add.at(e_in, rows[rows >= 0], 1)
        np.testing.assert_array_equal(e_in, be.e_in)
    finally:
        eng.close()


def test_tiered_engine_delete_out_of_range_ignored(tmp_path, dataset):
    """Out-of-range / already-dead ids are ignored, matching the device
    path's clip semantics (used to IndexError past disk capacity)."""
    eng = make_engine(tmp_path, dataset)
    try:
        eng.delete(np.array([-5, 0, N + 10, eng.cfg.disk_capacity + 600]))
        eng.delete(np.array([0]))          # double-delete: no-op
        assert eng.stats()["alive"] == N - 1
    finally:
        eng.close()


def test_tiered_engine_prefetch_populates_window(tmp_path, dataset):
    eng = make_engine(tmp_path, dataset, prefetch=True, prefetch_budget=64)
    try:
        rng = np.random.default_rng(3)
        for _ in range(3):
            eng.search(rng.normal(size=(16, D)).astype(np.float32))
        import time
        time.sleep(0.3)   # let the prefetcher drain
        assert eng.state.tiered.store.prefetched > 0
    finally:
        eng.close()


# ---------------------------------------------------------------------------
# MVCC-snapshotted tiered consolidation (paper §5.3 on the disk tier)
# ---------------------------------------------------------------------------

def test_tiered_mvcc_protocol_no_lost_writes(tmp_path, dataset):
    """Deterministic replay of the snapshot/merge protocol: inserts and
    deletes land in the window between snapshot and merge; after the merge
    every acknowledged write survives — new vertices keep their rows and
    reverse edges, window deletions are authoritative."""
    eng = make_engine(tmp_path, dataset, consolidate_threshold=2.0)
    try:
        rng = np.random.default_rng(7)
        be = eng.state.tiered
        eng.delete(np.arange(0, 500))            # pre-snapshot deletions
        snap = mvcc.snapshot_tiered(be)

        # window ops on the active log while "consolidation runs": TWO
        # insert batches, so the merge must replay the reverse-edge logs
        # batch by batch (a single concatenated replay would collapse a
        # target's window edges onto one slot and drop the earlier batch)
        newv = rng.normal(size=(40, D)).astype(np.float32)
        ids, rev = U.insert_tiered(be, eng._placement, newv,
                                   eng.cfg.search, seed=11)
        newv2 = rng.normal(size=(40, D)).astype(np.float32)
        ids2, rev2 = U.insert_tiered(be, eng._placement, newv2,
                                     eng.cfg.search, seed=12)
        eng.delete(np.arange(500, 560))          # window deletions
        win_dead = np.arange(500, 560)
        # vertices inserted AND deleted within the same window: their
        # live-applied reverse edges must not survive the merge even on
        # rows the rebuild never touched
        eng.delete(ids2[-8:])
        ids2, newv2 = ids2[:-8], newv2[:-8]

        new_rows = U.consolidate_tiered(be, snapshot=snap)
        mvcc.merge_consolidated_tiered(be, snap, new_rows, [rev, rev2])

        # acknowledged inserts survive: alive, rows intact, reachable
        assert be.alive[ids].all() and be.alive[ids2].all()
        _, rows = be.store.peek(np.arange(be.n))
        # reverse-edge integration: BOTH batches' ids appear in old rows
        assert np.isin(ids, rows[:snap.n]).any()
        assert np.isin(ids2, rows[:snap.n]).any()
        found, _ = eng.search(newv)
        assert float((found[:, 0] == ids).mean()) > 0.9
        found2_, _ = eng.search(newv2)
        assert float((found2_[:, 0] == ids2).mean()) > 0.9
        # window deletions stay authoritative (rows cleared, edges gone)
        assert not be.alive[win_dead].any()
        assert (rows[win_dead] == -1).all()
        dead_edges = (rows >= 0) & ~be.alive[np.clip(rows, 0, None)]
        assert dead_edges.sum() == 0
        # e_in rebuilt consistently with the merged rows
        e_in = np.zeros((be.capacity,), np.int32)
        np.add.at(e_in, rows[rows >= 0], 1)
        np.testing.assert_array_equal(e_in, be.e_in)
    finally:
        eng.close()


def test_tiered_mvcc_concurrent_consolidation(tmp_path, dataset):
    """Engine-level: a consolidation pass overlapping live inserts,
    deletes and searches loses no acknowledged write, and recall matches
    a serial (non-overlapped) run of the same workload."""
    rng = np.random.default_rng(9)
    queries = rng.normal(size=(32, D)).astype(np.float32)
    inserts = [rng.normal(size=(32, D)).astype(np.float32)
               for _ in range(4)]

    def run(tag, overlap):
        eng = make_engine(tmp_path / tag, dataset, consolidate_threshold=2.0)
        try:
            eng.delete(np.arange(0, 600))
            if not overlap:
                eng.consolidate_async(wait=True)     # serial reference
                th = None
            else:
                th = eng.consolidate_async(wait=False)
            acked = []
            for part in inserts:
                acked.append(eng.insert(part))
                eng.search(queries)
                eng.delete(np.arange(600, 610))      # idempotent re-deletes
            if th is not None:
                th.join()
            eng.wait_background()
            be = eng.state.tiered
            # no acknowledged insert lost
            for ids, part in zip(acked, inserts):
                assert be.alive[ids].all(), f"{tag}: lost inserted ids"
                found, _ = eng.search(part)
                assert float((found[:, 0] == ids).mean()) > 0.9, \
                    f"{tag}: inserted vectors unreachable"
            assert not be.alive[:610].any()
            found, _ = eng.search(queries)
            mirror = np.concatenate(
                [np.arange(610, N)] + [i for i in acked])
            mvecs = np.concatenate(
                [dataset[610:]] + inserts)
            d = ((queries[:, None, :] - mvecs[None]) ** 2).sum(-1)
            truth = mirror[np.argsort(d, axis=1)[:, :10]]
            hits = (found[:, :10, None] == truth[:, None, :]).any(1)
            return float(hits.mean())
        finally:
            eng.close()

    rec_serial = run("serial", overlap=False)
    rec_conc = run("conc", overlap=True)
    assert rec_conc >= 0.8
    assert rec_conc >= rec_serial - 0.05, (rec_conc, rec_serial)


# ---------------------------------------------------------------------------
# TieredStore semantics
# ---------------------------------------------------------------------------

def test_tiered_store_wavp_demotion_order(tmp_path):
    """Host-window demotion follows ascending F_λ — the same predictor
    that ranks device-cache promotion (paper §4.3)."""
    n, dim = 128, 8
    disk = DiskTier(str(tmp_path), n, dim, 4)
    data = np.random.default_rng(0).normal(size=(n, dim)).astype(np.float32)
    disk.write(np.arange(n), data, np.zeros((n, 4), np.int32))
    store = TieredStore(disk, host_slots=16)
    f_lam = C.f_lambda_np(np.zeros(n), np.arange(n))  # ascending in id
    store.fetch(np.arange(16), f_lam)                 # fill the window
    store.fetch(np.arange(100, 108), f_lam)           # hotter rows arrive
    # the 8 coldest residents (ids 0..7) were demoted, hottest retained
    assert (store.loc[np.arange(8)] == -1).all()
    assert (store.loc[np.arange(8, 16)] >= 0).all()
    assert (store.loc[np.arange(100, 108)] >= 0).all()
    assert store.demotions == 8


def test_tiered_store_write_through_coherence(tmp_path):
    n, dim = 64, 4
    disk = DiskTier(str(tmp_path), n, dim, 4)
    data = np.zeros((n, dim), np.float32)
    disk.write(np.arange(n), data, np.full((n, 4), -1, np.int32))
    store = TieredStore(disk, host_slots=8)
    store.fetch(np.arange(4))                     # resident
    upd = np.full((2, dim), 7.0, np.float32)
    store.write(np.array([1, 50]), upd)           # one resident, one not
    v, _ = store.fetch(np.array([1, 50]))
    np.testing.assert_allclose(v, 7.0)
    # peek must not promote or count
    h, m = store.hits, store.misses
    store.peek(np.arange(40, 60))
    assert (store.hits, store.misses) == (h, m)
    assert store.loc[55] == -1
    # rows-only peek: same overlay semantics, still no promote/count
    _, full_rows = store.peek(np.arange(0, 60))
    np.testing.assert_array_equal(store.peek_rows(np.arange(0, 60)),
                                  full_rows)
    assert (store.hits, store.misses) == (h, m)
    assert store.loc[55] == -1


def test_tiered_store_concurrent_fetch_stress(tmp_path):
    """Two foreground threads + the background prefetcher hammer an
    8x-oversubscribed window; residency must stay bijective and contents
    exact."""
    n, dim = 512, 16
    disk = DiskTier(str(tmp_path), n, dim, 4)
    rng = np.random.default_rng(0)
    data = rng.normal(size=(n, dim)).astype(np.float32)
    disk.write(np.arange(n), data, np.zeros((n, 4), np.int32))
    store = TieredStore(disk, host_slots=64)
    store.start_prefetcher()
    f_lam = rng.random(n)
    errors = []

    def worker(seed):
        try:
            r = np.random.default_rng(seed)
            for _ in range(150):
                ids = r.integers(0, n, 48)
                v, _ = store.fetch(ids, f_lam)
                np.testing.assert_allclose(v, data[ids], rtol=1e-6)
                store.prefetch(r.integers(0, n, 16), f_lam)
        except Exception as e:  # pragma: no cover
            errors.append(e)

    ths = [threading.Thread(target=worker, args=(s,)) for s in (1, 2)]
    for t in ths:
        t.start()
    for t in ths:
        t.join()
    store.stop()
    assert not errors, errors[0]
    # residency directory is a bijection window<->ids
    occ = store.slot_id >= 0
    assert occ.sum() == (store.loc >= 0).sum()
    np.testing.assert_array_equal(
        store.loc[store.slot_id[occ]], np.where(occ)[0])
    # resident rows hold the true contents
    res_ids = store.slot_id[occ]
    np.testing.assert_allclose(store.host_vec[store.loc[res_ids]],
                               data[res_ids], rtol=1e-6)


# ---------------------------------------------------------------------------
# bandwidth-tier dtype regression
# ---------------------------------------------------------------------------

def test_apply_wavp_preserves_cache_dtype():
    """A bf16 device cache must stay bf16 through a placement pass (the
    fp32 scatter-pad used to silently double device-cache memory)."""
    rng = np.random.default_rng(0)
    vecs = rng.normal(size=(512, D)).astype(np.float32)
    st = build_index(vecs, degree=8, cache_slots=64, n_max=1024)
    st = st._replace(cache=st.cache._replace(
        vectors=st.cache.vectors.astype(jnp.bfloat16)))
    sp = SearchParams(k=4, pool=32, max_iters=32)
    res = search_batch(st, jnp.asarray(vecs[:8]), jax.random.PRNGKey(0), sp)
    st2 = C.apply_wavp(st, res.acc_ids, res.acc_hit, sp)
    assert st2.cache.vectors.dtype == jnp.bfloat16
    assert int(st2.stats.promotions) >= 0  # pass ran
