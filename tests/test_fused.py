"""Fused multi-round executor tests.

The device-resident topology tier (``cache.TopoCache``) + K-round
``lax.while_loop`` dispatch must be *bitwise transparent*: whatever the
topology hit rate, the fused executor returns exactly the per-round
executor's results — pinned here across K ∈ {1, 2, 4} and uncapped,
under forced 100% residency (full-warm cache), forced 0% residency
(zero-slot cache: every round runs the per-round fallback), demand
installs from cold, and interleaved insert/delete batches that move the
store's write epoch (the fence re-reads every resident row wholesale).
Also pins the row_gather kernel against its jnp oracle and the dispatch
economics: a warm topology collapses ~rounds+2 dispatches to 3
(entry + fused loop + re-rank)."""
import tempfile

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:   # no network route: replay fixed seeded examples
    from _hypothesis_shim import given, settings, st

from repro.core import cache as C
from repro.core import quant, update
from repro.core.build import build_tiered_backend
from repro.core.engine import EngineConfig, SVFusionEngine
from repro.core.search import search_tiered
from repro.core.types import SearchParams

D = 12


def _make(tmp, n, deg, seed=0):
    rng = np.random.default_rng(seed)
    vecs = rng.normal(size=(n, D)).astype(np.float32)
    be = build_tiered_backend(vecs, deg, tmp, disk_capacity=4 * n,
                              host_window=max(32, n // 4))
    hp = C.HostPlacement(be.capacity, 64, D)
    cb = quant.train_codebook(vecs, m=4, bits=6, iters=5, seed=seed)
    pq = quant.PQCodes(cb, be.capacity, codes=quant.encode(cb, vecs))
    be.attach_pq(pq)
    return vecs, be, hp, pq


def _warm_topo(be, slots=None):
    """A TopoCache holding every live row (forced 100% hit rate)."""
    topo = C.TopoCache(be.capacity, slots or be.capacity, be.degree)
    topo.validate(be.store)
    live = np.flatnonzero(np.asarray(be.alive[:be.n]))
    topo.install(live, be.store.peek_rows(live))
    return topo


# ---------------------------------------------------------------------------
# row_gather kernel vs oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("S,R,N,B,W", [
    (64, 8, 200, 2, 4), (16, 16, 64, 3, 8), (128, 4, 500, 1, 16),
])
def test_row_gather_kernel_matches_ref(S, R, N, B, W):
    """Kernel (interpret mode) vs jnp oracle, with idle (-1) frontier
    lanes and non-resident ids (h2s == -1) mixed in — both must surface
    as all--1 rows."""
    from repro.kernels.row_gather.kernel import row_gather
    from repro.kernels.row_gather.ref import row_gather_ref
    rng = np.random.default_rng(S + R)
    table = rng.integers(-1, N, (S, R)).astype(np.int32)
    h2s = np.full((N,), -1, np.int32)
    res = rng.permutation(N)[:S]            # S resident ids
    h2s[res] = np.arange(S)
    ids = rng.integers(0, N, (B, W)).astype(np.int32)
    ids[rng.random((B, W)) < 0.3] = -1      # idle lanes
    out = np.asarray(row_gather(table, h2s, ids, interpret=True))
    ref = np.asarray(row_gather_ref(table, h2s, ids))
    np.testing.assert_array_equal(out, ref)
    bad = (ids < 0) | (h2s[np.clip(ids, 0, None)] < 0)
    assert (out[bad] == -1).all()
    ok = ~bad
    if ok.any():
        np.testing.assert_array_equal(out[ok], table[h2s[ids[ok]]])


# ---------------------------------------------------------------------------
# bitwise parity: fused executor vs per-round executor
# ---------------------------------------------------------------------------

@settings(max_examples=4, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(80, 240),
       st.sampled_from([1, 2, 4, 0]))
def test_fused_bit_identical_to_per_round(seed, n, K):
    """Property: whatever the K-round budget (0 = uncapped) and whatever
    the topology residency — full (100% hits), empty (0% hits: the
    per-round fallback serves every round), or demand-filled from cold —
    the fused executor's ids, distances AND per-round visit log are
    bit-identical to the per-round executor's."""
    rng = np.random.default_rng(seed)
    with tempfile.TemporaryDirectory() as td:
        vecs, be, hp, pq = _make(td, n, 8, seed=seed % 97)
        try:
            queries = rng.normal(size=(4, D)).astype(np.float32)
            sp = SearchParams(k=5, pool=16, max_iters=24, beam=2)
            entries = rng.integers(0, n, (4, sp.pool))
            base = search_tiered(be, hp, queries, 0, sp,
                                 entry_ids=entries, pq=pq,
                                 rerank_depth=sp.pool, speculate=False)
            for topo in (_warm_topo(be),                     # 100% hits
                         C.TopoCache(be.capacity, 0, 8),     # 0% hits
                         C.TopoCache(be.capacity, 64, 8)):   # demand fill
                got = search_tiered(be, hp, queries, 0, sp,
                                    entry_ids=entries, pq=pq,
                                    rerank_depth=sp.pool, speculate=False,
                                    topo=topo, fused_rounds=K)
                np.testing.assert_array_equal(got.ids, base.ids)
                np.testing.assert_array_equal(got.dists, base.dists)
                np.testing.assert_array_equal(got.acc_ids, base.acc_ids)
        finally:
            be.close()


def test_fused_forced_hit_rates_and_dispatch_budget():
    """Dispatch economics + counter wiring at the two forced extremes:
    a full-warm topology runs the whole walk in ONE fused dispatch
    (entry + loop + re-rank = 3 total, vs rounds+2 per-round) with zero
    misses; a zero-slot topology reports zero hits and needs exactly the
    per-round executor's dispatch count."""
    rng = np.random.default_rng(11)
    with tempfile.TemporaryDirectory() as td:
        vecs, be, hp, pq = _make(td, 220, 8, seed=1)
        try:
            queries = rng.normal(size=(4, D)).astype(np.float32)
            sp = SearchParams(k=5, pool=16, max_iters=24, beam=2)
            entries = rng.integers(0, 220, (4, sp.pool))
            kw = dict(entry_ids=entries, pq=pq, rerank_depth=sp.pool,
                      speculate=False)
            base = search_tiered(be, hp, queries, 0, sp, **kw)
            warm = search_tiered(be, hp, queries, 0, sp, **kw,
                                 topo=_warm_topo(be))
            assert warm.dispatches == 3 < base.dispatches
            assert warm.topo_misses == 0 and warm.topo_hits > 0
            assert warm.topo_hit_rate == 1.0
            cold = search_tiered(be, hp, queries, 0, sp, **kw,
                                 topo=C.TopoCache(be.capacity, 0, 8))
            assert cold.topo_hits == 0 and cold.topo_misses > 0
            assert cold.topo_hit_rate == 0.0
            assert cold.dispatches == base.dispatches
            # speculation stays transparent through the fused shell too
            spec = search_tiered(be, hp, queries, 0, sp,
                                 entry_ids=entries, pq=pq,
                                 rerank_depth=sp.pool, speculate=True,
                                 topo=C.TopoCache(be.capacity, 64, 8))
            np.testing.assert_array_equal(spec.ids, base.ids)
            np.testing.assert_array_equal(spec.acc_ids, base.acc_ids)
        finally:
            be.close()


def test_fused_epoch_flush_on_interleaved_updates(tmp_path):
    """Interleaved insert/delete between fused searches: the write-epoch
    fence re-reads every resident row (TopoCache.flushes advances), so a
    post-update fused search is bit-identical to a per-round search over
    the mutated graph — cached topology is never served stale."""
    rng = np.random.default_rng(5)
    n = 260
    vecs, be, hp, pq = None, None, None, None
    vecs, be, hp, pq = _make(str(tmp_path), n, 8, seed=2)
    try:
        topo = _warm_topo(be)
        sp = SearchParams(k=5, pool=16, max_iters=24, beam=2)
        queries = rng.normal(size=(4, D)).astype(np.float32)
        entries = rng.integers(0, n, (4, sp.pool))
        kw = dict(entry_ids=entries, pq=pq, rerank_depth=sp.pool,
                  speculate=False)
        search_tiered(be, hp, queries, 0, sp, **kw, topo=topo)
        for batch in range(3):
            newv = rng.normal(size=(8, D)).astype(np.float32)
            ids, _ = update.insert_tiered(be, hp, newv, sp, 100 + batch)
            dead = np.asarray(ids[:3], np.int64)
            be.alive[dead] = False          # engine.delete's tiered path
            be.version[dead] += 1
            base = search_tiered(be, hp, queries, 0, sp, **kw)
            got = search_tiered(be, hp, queries, 0, sp, **kw, topo=topo)
            np.testing.assert_array_equal(got.ids, base.ids)
            np.testing.assert_array_equal(got.dists, base.dists)
            np.testing.assert_array_equal(got.acc_ids, base.acc_ids)
        assert topo.flushes >= 3     # every insert batch moved the epoch
        # residency survived the flushes (rows re-read, not dropped), so
        # the mutated-but-resident part of the walk still fuses
        assert topo.resident >= n
    finally:
        be.close()


def test_engine_fused_dispatch_budget_and_stats(tmp_path):
    """Engine wiring: PQ-on engines build + warm the topology tier at
    init, steady-state batches cost 3 dispatches, and ``stats()`` is the
    single source for the acceptance metric (``dispatches_per_query``,
    fed by the per-result counters) plus the topology hit-rate."""
    rng = np.random.default_rng(9)
    n = 500
    vecs = rng.normal(size=(n, 16)).astype(np.float32)
    eng = SVFusionEngine(vecs, EngineConfig(
        degree=8, cache_slots=64, capacity=4 * n,
        disk_path=str(tmp_path / "t"), disk_capacity=4 * n,
        host_window=n // 4, search=SearchParams(k=8, pool=32, max_iters=48),
        seed=0, pq_enabled=True, pq_m=4, pq_bits=6, coalesce=False))
    try:
        q = rng.normal(size=(8, 16)).astype(np.float32)
        for _ in range(4):
            eng.search(q)
        st = eng.stats()
        assert st["dispatches_per_query"] <= 3.0
        assert st["topo_hit_rate"] == 1.0
        assert st["topo_misses"] == 0
        assert st["bytes_per_tier"]["device_topo_rows"] > 0
        # tier_counts surfaces the TopoCache counters
        assert st["topo_resident"] >= n
        # knob: topo_cache_slots < 0 disables the fused path entirely
    finally:
        eng.close()


def test_engine_topo_disabled_knob(tmp_path):
    """``topo_cache_slots=-1`` opts out of the fused path: no topology
    tier is attached and dispatch counts match the per-round executor."""
    rng = np.random.default_rng(13)
    n = 400
    vecs = rng.normal(size=(n, 16)).astype(np.float32)
    eng = SVFusionEngine(vecs, EngineConfig(
        degree=8, cache_slots=64, capacity=2 * n,
        disk_path=str(tmp_path / "t"), disk_capacity=2 * n,
        host_window=n // 4, search=SearchParams(k=8, pool=32, max_iters=48),
        seed=0, pq_enabled=True, pq_m=4, pq_bits=6, coalesce=False,
        topo_cache_slots=-1))
    try:
        q = rng.normal(size=(8, 16)).astype(np.float32)
        eng.search(q)
        st = eng.stats()
        assert st["dispatches_per_query"] > 3
        assert st["topo_hits"] == 0 and st["topo_misses"] == 0
        assert "topo_resident" not in st
    finally:
        eng.close()
