"""Minimal offline stand-in for the ``hypothesis`` API used by this suite.

The pinned environment has no network route, so ``pip install hypothesis``
is not an option. This shim implements the tiny subset the tests need —
``@given`` over ``strategies.integers`` with ``@settings`` — by replaying a
deterministic, seeded set of drawn examples per strategy. Boundary values
(min and max of each strategy) are always included, the rest are drawn
from a generator seeded by the test function's qualified name, so runs are
reproducible without any dependency.

Test modules import it as::

    try:
        from hypothesis import given, settings
        from hypothesis import strategies as st
    except ImportError:
        from tests._hypothesis_shim import given, settings
        from tests._hypothesis_shim import strategies as st
"""
from __future__ import annotations

import functools
import inspect
import zlib

import numpy as np

# Replay cap: property bodies here jit-compile per distinct shape (a few
# seconds each for interpret-mode Pallas), so a bounded, deterministic
# example set keeps the suite practical while still covering both
# boundaries + a random sample.
MAX_REPLAY = 8


class _Strategy:
    def __init__(self, draw, boundaries=()):
        self.draw = draw
        self.boundaries = tuple(boundaries)


class strategies:
    """Namespace mirror of ``hypothesis.strategies``."""

    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)),
            boundaries=(int(min_value), int(max_value)))

    @staticmethod
    def floats(min_value, max_value):
        return _Strategy(
            lambda rng: float(rng.uniform(min_value, max_value)),
            boundaries=(float(min_value), float(max_value)))

    @staticmethod
    def booleans():
        return _Strategy(lambda rng: bool(rng.integers(0, 2)),
                         boundaries=(False, True))

    @staticmethod
    def sampled_from(elements):
        elements = list(elements)
        # boundaries: first and last, mirroring hypothesis's shrink targets
        return _Strategy(
            lambda rng: elements[int(rng.integers(0, len(elements)))],
            boundaries=(elements[0], elements[-1]))


st = strategies


def settings(max_examples=None, deadline=None, **_ignored):
    """Records the example budget on the (possibly given-wrapped) function."""
    def deco(fn):
        if max_examples is not None:
            fn._hyp_max_examples = max_examples
        return fn
    return deco


def given(*strats):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            budget = getattr(wrapper, "_hyp_max_examples", MAX_REPLAY)
            n = min(budget, MAX_REPLAY)
            seed = zlib.crc32(fn.__qualname__.encode())
            rng = np.random.default_rng(seed)
            examples = []
            # boundary example: every strategy at min, then every at max
            if strats and all(s.boundaries for s in strats):
                examples.append(tuple(s.boundaries[0] for s in strats))
                examples.append(tuple(s.boundaries[-1] for s in strats))
            while len(examples) < n:
                examples.append(tuple(s.draw(rng) for s in strats))
            for ex in examples[:n]:
                fn(*args, *ex, **kwargs)
        # hide the strategy-bound trailing params from pytest's fixture
        # resolution (real hypothesis does the same)
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())
        wrapper.__signature__ = sig.replace(
            parameters=params[:len(params) - len(strats)])
        del wrapper.__wrapped__
        return wrapper
    return deco
