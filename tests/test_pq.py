"""PQ subsystem tests: pq_adc kernel-vs-ref parity, codebook
reconstruction bounds, ADC-vs-exact rank fidelity (property), the
coarse-then-refine executor lane (rerank_depth == pool parity against the
exact tiered arm), incremental write-through encoding under interleaved
updates, per-tier byte accounting, and the bench gate's config-key
comparability."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:   # no network route: replay fixed seeded examples
    from _hypothesis_shim import given, settings, st

from repro.core import cache as C
from repro.core import quant
from repro.core.build import build_tiered_backend
from repro.core.engine import EngineConfig, SVFusionEngine
from repro.core.search import search_tiered
from repro.core.types import SearchParams
from repro.kernels.pq_adc.kernel import pq_adc
from repro.kernels.pq_adc.ref import pq_adc_ref

KEY = jax.random.PRNGKey(11)


def _lossless_codes(vecs, capacity):
    """A PQ lane that is lossless BY CONSTRUCTION: m = D subspaces of one
    dim, centroid k of subspace s is vecs[k, s], and vector i's code is
    simply i — so decode(codes) == vecs exactly and the ADC distance is
    the true squared distance (summed subspace-wise). Needs n <= 256."""
    n, D = vecs.shape
    assert n <= 256
    cents = np.full((D, 256, 1), 1e6, np.float32)   # far sentinels
    cents[:, :n, 0] = vecs.T
    cb = quant.PQCodebook(centroids=jnp.asarray(cents))
    codes = np.tile(np.arange(n, dtype=np.uint8)[:, None], (1, D))
    return quant.PQCodes(cb, capacity, codes=codes)


# ---------------------------------------------------------------------------
# kernel vs ref parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("N,m,K,B,Cw", [
    (256, 8, 64, 2, 8), (512, 16, 256, 3, 32), (128, 4, 16, 1, 4),
    (300, 6, 128, 2, 96), (400, 16, 256, 2, 200),   # > one VMEM tile
])
def test_pq_adc_matches_ref(N, m, K, B, Cw):
    codes = jax.random.randint(KEY, (N, m), 0, K).astype(jnp.uint8)
    lut = jax.random.uniform(jax.random.PRNGKey(1), (B, m, K))
    ids = jax.random.randint(jax.random.PRNGKey(2), (B, Cw), 0, N)
    out = pq_adc(codes, lut, ids, interpret=True)
    ref = pq_adc_ref(codes, lut, ids)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_pq_adc_invalid_lanes_masked():
    """The frontier executor feeds -1 lanes (padded beam slots, pruned
    edges): clamp the DMA index, return +inf, never index codes at -1 —
    the l2_gather contract on the code lane."""
    codes = jax.random.randint(KEY, (64, 8), 0, 16).astype(jnp.uint8)
    lut = jax.random.uniform(KEY, (2, 8, 16))
    ids = jnp.array([[-1, 5, -1, 0, 63, -1, 7, 2],
                     [1, -1, 1, 1, -1, 62, 0, -1]])
    out = np.asarray(pq_adc(codes, lut, ids, interpret=True))
    ref = np.asarray(pq_adc_ref(codes, lut, ids))
    mask = np.asarray(ids) < 0
    assert np.isinf(out[mask]).all() and np.isinf(ref[mask]).all()
    np.testing.assert_allclose(out[~mask], ref[~mask], rtol=1e-5, atol=1e-5)


def test_pq_adc_round_batched_id_matrix():
    """Executor round shape: (Q, beam·degree) id matrix with cross-beam
    duplicates and -1 padding."""
    beam, deg = 4, 16
    rng = np.random.default_rng(0)
    codes = jnp.asarray(rng.integers(0, 256, (400, 16)), jnp.uint8)
    lut = jax.random.uniform(KEY, (3, 16, 256))
    ids = rng.integers(0, 400, (3, beam * deg))
    ids[:, rng.integers(0, beam * deg, 11)] = -1
    ids[0, :deg] = ids[0, deg:2 * deg]            # cross-beam duplicates
    ids = jnp.asarray(ids, jnp.int32)
    out = pq_adc(codes, lut, ids, interpret=True)
    ref = pq_adc_ref(codes, lut, ids)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-4)


# ---------------------------------------------------------------------------
# codebook training / encode / decode
# ---------------------------------------------------------------------------

def test_encode_decode_reconstruction_bound():
    """Trained Lloyd codebooks must beat the trivial single-centroid
    quantizer by a wide margin: reconstruction MSE under 15% of the
    per-dim variance at K=64 on gaussian data (one centroid == 100%)."""
    rng = np.random.default_rng(0)
    vecs = rng.normal(size=(600, 16)).astype(np.float32)
    cb = quant.train_codebook(vecs, m=8, bits=6, iters=15, seed=0)
    codes = quant.encode(cb, vecs)
    assert codes.shape == (600, 8) and codes.dtype == np.uint8
    rec = quant.decode(cb, codes)
    mse = float(((rec - vecs) ** 2).mean())
    assert mse < 0.15 * float(vecs.var()), mse


def test_encode_chunked_matches_unchunked():
    rng = np.random.default_rng(1)
    vecs = rng.normal(size=(1000, 8)).astype(np.float32)
    cb = quant.train_codebook(vecs, m=4, bits=5, iters=8, seed=0)
    np.testing.assert_array_equal(quant.encode(cb, vecs, chunk=128),
                                  quant.encode(cb, vecs, chunk=4096))


def test_lossless_codebook_roundtrip_exact():
    rng = np.random.default_rng(2)
    vecs = rng.normal(size=(200, 6)).astype(np.float32)
    pq = _lossless_codes(vecs, 256)
    np.testing.assert_array_equal(
        quant.decode(pq.codebook, pq.codes[:200]), vecs)


def test_choose_m_divisor():
    assert quant.choose_m(32, 16) == 16
    assert quant.choose_m(24, 16) == 12
    assert quant.choose_m(17, 16) == 1
    assert quant.choose_m(8, 64) == 8


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(150, 400))
def test_adc_rank_fidelity_property(seed, n):
    """ADC distances must preserve exact-distance ranking closely enough
    to steer the traversal: Spearman rank correlation >= 0.9 over the
    dataset and >= half the exact top-10 recovered in the ADC top-10 —
    the coarse half of coarse-then-refine (the re-rank stage supplies
    exactness, but only over candidates the ADC ranking surfaced)."""
    rng = np.random.default_rng(seed)
    vecs = rng.normal(size=(n, 16)).astype(np.float32)
    q = rng.normal(size=(4, 16)).astype(np.float32)
    cb = quant.train_codebook(vecs, m=8, bits=6, iters=12, seed=seed % 97)
    codes = jnp.asarray(quant.encode(cb, vecs))
    lut = quant.adc_lut(cb.centroids, jnp.asarray(q))
    ids = jnp.tile(jnp.arange(n, dtype=jnp.int32)[None], (4, 1))
    d_adc = np.asarray(pq_adc_ref(codes, lut, ids))
    d_ex = ((vecs[None] - q[:, None]) ** 2).sum(-1)
    for b in range(4):
        ra = np.argsort(np.argsort(d_adc[b]))
        re = np.argsort(np.argsort(d_ex[b]))
        rho = float(np.corrcoef(ra, re)[0, 1])
        assert rho >= 0.9, rho
        top_a = set(np.argsort(d_adc[b])[:10].tolist())
        top_e = set(np.argsort(d_ex[b])[:10].tolist())
        assert len(top_a & top_e) >= 5, (top_a, top_e)


# ---------------------------------------------------------------------------
# executor code lane: parity + coarse-then-refine behavior
# ---------------------------------------------------------------------------

def test_pq_rerank_full_pool_parity_with_exact_arm():
    """Acceptance pin: with a lossless codebook and rerank_depth == pool,
    PQ-then-full-rerank must return the exact tiered executor's results —
    ids bit-identical, distances bit-identical (the re-rank recomputes
    them with the same jitted ``_batch_sqdist`` the exact arm uses)."""
    rng = np.random.default_rng(3)
    n, D, deg = 220, 12, 8
    vecs = rng.normal(size=(n, D)).astype(np.float32)
    queries = rng.normal(size=(4, D)).astype(np.float32)
    sp = SearchParams(k=5, pool=16, max_iters=24, beam=2)
    entries = rng.integers(0, n, (4, sp.pool))
    with tempfile.TemporaryDirectory() as td:
        be = build_tiered_backend(vecs, deg, td, host_window=64)
        hp = C.HostPlacement(be.capacity, 16, D)
        try:
            want = search_tiered(be, hp, queries, 0, sp,
                                 entry_ids=entries)
            pq = _lossless_codes(vecs, be.capacity)
            be.attach_pq(pq)
            got = search_tiered(be, hp, queries, 0, sp, entry_ids=entries,
                                pq=pq, rerank_depth=sp.pool)
            np.testing.assert_array_equal(got.ids, want.ids)
            np.testing.assert_array_equal(got.dists, want.dists)
            # speculation must stay transparent on the code lane too
            got2 = search_tiered(be, hp, queries, 0, sp,
                                 entry_ids=entries, pq=pq,
                                 rerank_depth=sp.pool, speculate=False)
            np.testing.assert_array_equal(got2.ids, want.ids)
        finally:
            be.close()


def test_pq_lane_no_per_round_vector_fetch():
    """The tentpole invariant: with PQ on, rounds move adjacency rows
    only — the vector cascade is touched by the entry/re-rank stages
    alone, so the store's counted reads drop to the re-rank set."""
    rng = np.random.default_rng(4)
    n, D, deg = 400, 16, 8
    vecs = rng.normal(size=(n, D)).astype(np.float32)
    queries = rng.normal(size=(8, D)).astype(np.float32)
    sp = SearchParams(k=10, pool=32, max_iters=48, beam=4)
    with tempfile.TemporaryDirectory() as td:
        be = build_tiered_backend(vecs, deg, td, host_window=100)
        hp = C.HostPlacement(be.capacity, 16, D)
        try:
            cb = quant.train_codebook(vecs, m=8, bits=6, iters=10, seed=0)
            pq = quant.PQCodes(cb, be.capacity,
                               codes=quant.encode(cb, vecs))
            be.attach_pq(pq)
            rerank = 16
            res = search_tiered(be, hp, queries, 0, sp, pq=pq,
                                rerank_depth=rerank, speculate=False)
            s = be.store
            # every counted access is either a row fetch (rounds) or a
            # re-rank vector fetch; re-rank unique ids <= B * rerank
            row_accesses = res.iters * sp.beam * len(queries)
            assert s.hits + s.misses <= row_accesses + len(queries) * rerank
            assert (res.dists[res.ids >= 0] >= 0).all()
        finally:
            be.close()


def test_pq_engine_insert_then_search_incremental_encode(tmp_path):
    """Interleaved insert/delete/search through the engine with PQ on:
    write-through incremental encoding must make streamed vectors
    reachable (read-after-write top-1) and deletions invisible, across
    several interleaved batches."""
    rng = np.random.default_rng(5)
    N, D = 1500, 24
    vecs = rng.normal(size=(N, D)).astype(np.float32)
    sp = SearchParams(k=10, pool=64, max_iters=96)
    eng = SVFusionEngine(vecs, EngineConfig(
        degree=16, cache_slots=256, capacity=8192,
        disk_path=str(tmp_path / "tier"), disk_capacity=8192,
        host_window=375, search=sp, pq_enabled=True, pq_m=12,
        pq_bits=8, rerank_depth=32))
    try:
        assert eng.state.tiered.pq is not None
        acked = []
        for i in range(3):
            newv = rng.normal(size=(32, D)).astype(np.float32)
            ids = eng.insert(newv)
            acked.append((ids, newv))
            found, dists = eng.search(newv)
            assert float((found[:, 0] == ids).mean()) > 0.9
            assert (np.diff(dists, axis=1) >= -1e-5).all()
            if i:   # delete the previous batch, must vanish
                pids, pvecs = acked[i - 1]
                eng.delete(pids)
                found2, _ = eng.search(pvecs)
                assert not np.isin(pids, found2).any()
        st = eng.stats()
        assert st["pq_encoded_incremental"] == 3 * 32
        # codes stayed unconditionally resident while WAVP manages only
        # exact slots: footprint ratio bounded by m / (4 * dim)
        assert st["device_footprint_ratio"] <= 12 / (4 * D) + 1e-9
        assert st["bytes_per_tier"]["device_codes"] == \
            int(st["n"]) * st["pq_m"]
    finally:
        eng.close()


def test_pq_engine_recall_and_footprint(tmp_path):
    """Acceptance: PQ-on tiered serving at window = dataset/4 reaches
    recall@10 >= 0.90 with the device code footprint <= 1/8 of the
    full-coverage fp32 equivalent."""
    from repro.core.build import build_graph
    from repro.core.search import brute_force_topk, recall_at_k
    rng = np.random.default_rng(6)
    N, D = 2400, 32
    vecs = rng.normal(size=(N, D)).astype(np.float32)
    sp = SearchParams(k=10, pool=64, max_iters=96)
    eng = SVFusionEngine(vecs, EngineConfig(
        degree=16, cache_slots=256, capacity=8192,
        disk_path=str(tmp_path / "tier"), disk_capacity=8192,
        host_window=N // 4, search=sp, pq_enabled=True, pq_m=16,
        pq_bits=8, rerank_depth=32))
    try:
        q = rng.normal(size=(32, D)).astype(np.float32)
        ids, _ = eng.search(q)
        truth, _ = brute_force_topk(build_graph(vecs, 16), jnp.asarray(q),
                                    10)
        rec = float(recall_at_k(jnp.asarray(ids), truth))
        assert rec >= 0.90, rec
        st = eng.stats()
        assert st["device_footprint_ratio"] <= 1 / 8 + 1e-9
    finally:
        eng.close()


def test_spec_rank_auto_probe_resolves(tmp_path):
    """spec_rank="auto" probes delta-fetch latency at startup and picks a
    concrete predictor; explicit overrides pass through untouched."""
    rng = np.random.default_rng(7)
    vecs = rng.normal(size=(600, 16)).astype(np.float32)
    sp = SearchParams(k=5, pool=32, max_iters=32)
    eng = SVFusionEngine(vecs, EngineConfig(
        degree=16, cache_slots=64, capacity=2048,
        disk_path=str(tmp_path / "auto"), disk_capacity=2048,
        host_window=150, search=sp, spec_rank="auto"))
    try:
        st_ = eng.stats()
        assert st_["spec_rank_resolved"] in ("flam", "dist")
        assert st_["spec_probe_us_per_row"] > 0
    finally:
        eng.close()
    eng = SVFusionEngine(vecs, EngineConfig(
        degree=16, cache_slots=64, capacity=2048,
        disk_path=str(tmp_path / "dist"), disk_capacity=2048,
        host_window=150, search=sp, spec_rank="dist"))
    try:
        assert eng.stats()["spec_rank_resolved"] == "dist"
        ids, _ = eng.search(rng.normal(size=(8, 16)).astype(np.float32))
        assert (ids[:, 0] >= 0).all()
    finally:
        eng.close()


def test_bench_gate_config_key_separates_pq_modes(tmp_path):
    """The bench gate must never compare a PQ-on entry against an
    exact-mode baseline: entries are keyed by config hash. Legacy entries
    (no pq/scale fields) key equal to fresh exact-mode runs."""
    import sys
    sys.path.insert(0, str(__import__("pathlib").Path(
        __file__).resolve().parent.parent))
    from benchmarks.bench_disk import _append_result, check_gate, config_key
    legacy = {"n": 100, "dim": 8, "smoke": True}
    exact = {"n": 100, "dim": 8, "smoke": True, "pq": False, "scale": False,
             "window_frac": 4}
    pqm = dict(exact, pq=True)
    assert config_key(legacy) == config_key(exact) != config_key(pqm)
    path = str(tmp_path / "hist.json")
    mk = lambda meta, qps, rec: {
        "meta": meta, "tiered_serving": {"search_qps": qps, "recall": rec}}
    _append_result(mk(legacy, 1000.0, 0.95), path)
    _append_result(mk(pqm, 500.0, 0.93), path)       # pq-on: no predecessor
    assert check_gate(path) == []                    # never gates vs exact
    _append_result(mk(pqm, 490.0, 0.93), path)       # pq vs pq: fine
    assert check_gate(path) == []
    _append_result(mk(pqm, 100.0, 0.93), path)       # pq regression: fails
    assert check_gate(path) != []
    _append_result(mk(exact, 990.0, 0.95), path)     # exact vs legacy: fine
    assert check_gate(path) == []


def test_bench_results_rotation(tmp_path):
    """Per-key retention cap with full history under archive/."""
    import json, os
    import sys
    sys.path.insert(0, str(__import__("pathlib").Path(
        __file__).resolve().parent.parent))
    from benchmarks.bench_disk import _append_result
    path = str(tmp_path / "hist.json")
    for i in range(7):
        _append_result({"meta": {"n": 1, "dim": 1, "smoke": True},
                        "i": i}, path, keep_per_key=3)
    with open(path) as f:
        kept = json.load(f)
    assert [e["i"] for e in kept] == [4, 5, 6]
    apath = os.path.join(str(tmp_path), "archive", "hist.json")
    with open(apath) as f:
        arch = json.load(f)
    assert [e["i"] for e in arch] == [0, 1, 2, 3]


def test_bench_gate_median_resample_rescues_noisy_run(tmp_path):
    """Gate robustness: a single noisy QPS sample below the floor must
    not fail the gate when the median of the entry's re-samples clears
    it; a genuinely regressed median still fails."""
    import sys
    sys.path.insert(0, str(__import__("pathlib").Path(
        __file__).resolve().parent.parent))
    from benchmarks.bench_disk import _append_result, check_gate, qps_floor
    meta = {"n": 100, "dim": 8, "smoke": True, "pq": True, "scale": False,
            "window_frac": 4}
    path = str(tmp_path / "hist.json")
    _append_result({"meta": meta, "tiered_serving":
                    {"search_qps": 1000.0, "recall": 0.95}}, path)
    assert qps_floor(meta, path=path) == 800.0
    # noisy headline number, but the median of 3 re-samples clears it
    _append_result({"meta": meta, "tiered_serving":
                    {"search_qps": 700.0, "recall": 0.95,
                     "qps_samples": [700.0, 950.0, 990.0]}}, path)
    assert check_gate(path) == []
    # median below the floor: regression is real, gate fails (fresh
    # history — the gate compares against the immediate predecessor)
    path2 = str(tmp_path / "hist2.json")
    _append_result({"meta": meta, "tiered_serving":
                    {"search_qps": 1000.0, "recall": 0.95}}, path2)
    _append_result({"meta": meta, "tiered_serving":
                    {"search_qps": 700.0, "recall": 0.95,
                     "qps_samples": [700.0, 710.0, 990.0]}}, path2)
    fails = check_gate(path2)
    assert fails and "median" in fails[0]


def test_bf16_exact_cache_halves_bytes_recall_within_bar(tmp_path):
    """The exact re-rank payload rides the device cache in bf16 (default
    ``cache_dtype``): the device exact-vector footprint halves while
    recall@10 stays within 0.005 of the fp32 cache — the re-rank
    distances are computed in fp32 either way, only the cached payload
    is rounded (~3 decimal digits, far below the inter-neighbor distance
    gaps of real data)."""
    rng = np.random.default_rng(21)
    n, dim = 900, 16
    vecs = rng.normal(size=(n, dim)).astype(np.float32)
    # enough queries that the delta estimate resolves well under the
    # 0.005 bar (each rank-10 near-tie flip moves recall by 1/(10 B))
    queries = rng.normal(size=(128, dim)).astype(np.float32)
    sp = SearchParams(k=10, pool=64, max_iters=96)
    truth = np.argsort(((vecs[None] - queries[:, None]) ** 2)
                       .sum(-1), axis=1)[:, :10]

    def run(dtype):
        eng = SVFusionEngine(vecs, EngineConfig(
            degree=16, cache_slots=256, capacity=2 * n,
            disk_path=str(tmp_path / dtype), disk_capacity=2 * n,
            host_window=n // 4, search=sp, seed=0, coalesce=False,
            pq_enabled=True, pq_m=8, pq_bits=8, rerank_depth=32,
            cache_dtype=dtype))
        try:
            for _ in range(3):     # converge the WAVP placement
                eng.search(queries, update_cache=True)
            ids, _ = eng.search(queries)
            st = eng.stats()
            rec = float(np.mean([len(set(ids[i, :10].tolist())
                                     & set(truth[i].tolist())) / 10
                                 for i in range(len(queries))]))
            return rec, st["bytes_per_tier"]["device_exact_cache"]
        finally:
            eng.close()

    rec16, bytes16 = run("bf16")
    rec32, bytes32 = run("fp32")
    assert bytes16 * 2 == bytes32
    assert abs(rec32 - rec16) < 0.005, (rec32, rec16)
    with pytest.raises(ValueError):
        SVFusionEngine(vecs[:64], EngineConfig(
            degree=8, cache_slots=16, capacity=128,
            disk_path=str(tmp_path / "bad"), disk_capacity=128,
            cache_dtype="fp64"))
