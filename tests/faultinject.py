"""Fault-injection harness for the durability subsystem (core/wal.py).

Two halves:

* ``arm(name)`` — in-process: installs a crash hook that ``os._exit(137)``s
  the process the Nth time execution passes the named crash point
  (``wal.CRASH_POINTS``), simulating a kill -9 at exactly that site.

* a subprocess driver (``python tests/faultinject.py <workload> <mode>``)
  that ``tests/test_durability.py`` runs as a child process so the crash
  actually kills something. Three modes over a deterministic workload
  (fixed dataset seed, fixed per-op arguments, no background scheduling —
  coalescer / speculation / prefetcher all off, fp32 cache):

  - ``crash``   build a fresh index in ``--dir``, run the workload, arm
                ``--crash-point`` just before op ``--crash-op``; the
                process must die with exit code 137 inside that op.
  - ``reopen``  recover the index from ``--dir`` (no init vectors) and
                dump a state digest (search results + full store state +
                the recovered WAL position) to ``--out``.
  - ``clean``   build the same index in a FRESH ``--dir`` and run exactly
                the first ``--records`` record-producing ops (checkpoints
                skipped — they never touch logical state), then dump the
                same digest to ``--out``.

The parent asserts the reopen digest is bit-identical to the clean digest
for the record-prefix the WAL proves durable: recovery lands the store in
a state the uninterrupted run passed through, never a torn one.

Every record-producing op maps to exactly ONE WAL record (inserts stay
under the engine's 512-row chunk, deletes are non-empty and disjoint, a
consolidation logs one CONSOLIDATE record), so the recovered WAL position
``last_seq`` IS the count of durable record ops — the parent derives the
clean run's ``--records`` from it and cross-checks the expected value per
crash point.
"""
from __future__ import annotations

import argparse
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.core import wal as walmod                       # noqa: E402

N, D, N0 = 768, 16, 256
SEARCH_SEED = 4242
CRASH_EXIT = 137

# op kinds: ("insert", (lo, hi)) | ("delete", (lo, hi)) |
#           ("consolidate", None) | ("checkpoint", None)
# Record-producing ops are everything but "checkpoint". Delete ranges are
# disjoint and target live ids only, so none filters down to empty.
WORKLOADS = {
    "insert_heavy": [
        ("insert", (256, 288)), ("insert", (288, 320)), ("insert", (320, 352)),
        ("checkpoint", None),
        ("insert", (352, 384)), ("insert", (384, 416)), ("insert", (416, 448)),
    ],
    "delete_heavy": [
        ("insert", (256, 320)), ("delete", (10, 40)), ("insert", (320, 384)),
        ("delete", (300, 330)),
        ("checkpoint", None),
        ("delete", (50, 80)), ("insert", (384, 448)),
    ],
    "consolidation": [
        ("insert", (256, 320)), ("delete", (0, 64)), ("delete", (100, 164)),
        ("consolidate", None), ("insert", (320, 384)),
    ],
}
# PQ variants exercise codebook persistence + replay re-encoding
WORKLOADS["insert_heavy_pq"] = WORKLOADS["insert_heavy"]
WORKLOADS["consolidation_pq"] = WORKLOADS["consolidation"]
# attrs variant exercises attribute-store persistence: extended INSERT
# WAL payloads, snapshot columns, replay re-writes
WORKLOADS["insert_heavy_attrs"] = WORKLOADS["insert_heavy"]


def arm(name: str, hits: int = 1) -> None:
    """Die with exit code 137 (kill -9's signature) the ``hits``-th time
    execution reaches crash point ``name``."""
    state = {"count": 0}

    def hook(point: str) -> None:
        if point == name:
            state["count"] += 1
            if state["count"] >= hits:
                os._exit(CRASH_EXIT)

    walmod.set_crash_hook(hook)


def record_ops(ops):
    return [op for op in ops if op[0] != "checkpoint"]


def expected_records(ops, crash_point: str, crash_op: int) -> int:
    """Durable WAL records after a crash at ``crash_point`` inside op
    ``crash_op``: every record op before it, plus the crashing op's own
    record when the point sits after its WAL append."""
    k = sum(1 for kind, _ in ops[:crash_op] if kind != "checkpoint")
    if crash_point in ("post_wal_append", "mid_memmap_write",
                       "mid_consolidation_merge"):
        k += 1
    return k


def dataset() -> np.ndarray:
    return np.random.default_rng(7).normal(size=(N, D)).astype(np.float32)


def attrs_for(lo: int, hi: int) -> dict:
    """Deterministic attribute payload for ids [lo, hi) — a pure function
    of the id so crash / reopen / clean runs agree bit-for-bit."""
    ids = np.arange(lo, hi)
    return {"cat": ids % 4, "score": ((ids % 97) / 97).astype(np.float32)}


def make_config(disk_path: str, pq: bool, attrs: bool = False):
    from repro.core.engine import EngineConfig
    from repro.core.types import SearchParams
    schema = None
    if attrs:
        from repro.core.filters import AttributeSchema
        schema = AttributeSchema(tag_fields=("cat",), num_fields=("score",))
    return EngineConfig(
        attributes=schema,
        degree=8, cache_slots=64, capacity=2048,
        search=SearchParams(k=8, pool=32, max_iters=32),
        disk_path=str(disk_path), disk_capacity=2048, host_window=96,
        seed=0, prefetch=False, speculate=False, coalesce=False,
        cache_dtype="fp32",
        consolidate_threshold=2.0,      # never auto-consolidate
        wal_enabled=True, wal_group_commit=4,
        snapshot_every_epochs=0,        # checkpoints only where scripted
        pq_enabled=pq, pq_m=4, pq_bits=6, pq_train_sample=512,
        rerank_depth=32)


def run_ops(eng, data, ops, *, crash_op=None, crash_point=None,
            max_records=None) -> int:
    done = 0
    for i, (kind, arg) in enumerate(ops):
        if max_records is not None:
            if kind == "checkpoint":
                continue                # durability-only: no logical effect
            if done >= max_records:
                break
        if crash_op is not None and i == crash_op:
            arm(crash_point)
        if kind == "insert":
            attrs = (attrs_for(*arg) if eng._backend.attrs is not None
                     else None)
            eng.insert(data[arg[0]:arg[1]], attributes=attrs)
        elif kind == "delete":
            eng.delete(np.arange(arg[0], arg[1]))
        elif kind == "consolidate":
            eng._consolidate_tiered_async(wait=True)
        elif kind == "checkpoint":
            eng.checkpoint()
        if kind != "checkpoint":
            done += 1
    return done


def dump_digest(eng, out_path: str, last_seq: int) -> None:
    """Full logical-state digest: parity search results plus every host
    structure recovery rebuilds. Bit-compared by the parent."""
    from repro.core.search import search_tiered
    from repro.core.types import SearchParams
    b = eng._backend
    n = int(b.n)
    q = np.random.default_rng(SEARCH_SEED).normal(size=(8, D)) \
        .astype(np.float32)
    res = search_tiered(b, eng._placement, q, SEARCH_SEED,
                        SearchParams(k=8, pool=32, max_iters=32),
                        speculate=False)
    ids = np.arange(n)
    arrays = dict(ids=np.asarray(res.ids), dists=np.asarray(res.dists),
                  nbr=b.store.peek_rows(ids), vec=b.store.peek(ids)[0],
                  alive=b.alive[:n].copy(), e_in=b.e_in.copy(),
                  version=b.version.copy(), n=np.asarray(n, np.int64),
                  last_seq=np.asarray(int(last_seq), np.int64))
    if b.pq is not None:
        arrays["pq_codes"] = b.pq.codes[:n].copy()
        from repro.core import quant
        arrays["pq_centroids"] = quant.codebook_to_array(b.pq.codebook)
    if b.attrs is not None:
        arrays["attr_tags"], arrays["attr_nums"] = b.attrs.snapshot(n)
        # a filtered parity search over the recovered attribute columns
        from repro.core.filters import FilterSpec
        fres = search_tiered(b, eng._placement, q, SEARCH_SEED,
                             SearchParams(k=8, pool=32, max_iters=32),
                             speculate=False,
                             filter=FilterSpec(tags={"cat": {0, 2}}))
        arrays["filt_ids"] = np.asarray(fres.ids)
        arrays["filt_dists"] = np.asarray(fres.dists)
    np.savez(out_path, **arrays)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("workload", choices=sorted(WORKLOADS))
    ap.add_argument("mode", choices=["crash", "reopen", "clean"])
    ap.add_argument("--dir", required=True, help="index directory")
    ap.add_argument("--out", help="digest .npz path (reopen/clean)")
    ap.add_argument("--crash-point", choices=walmod.CRASH_POINTS)
    ap.add_argument("--crash-op", type=int)
    ap.add_argument("--records", type=int,
                    help="clean mode: record-op prefix length to run")
    a = ap.parse_args(argv)

    from repro.core.engine import SVFusionEngine
    data = dataset()
    ops = WORKLOADS[a.workload]
    with_attrs = a.workload.endswith("_attrs")
    cfg = make_config(a.dir, pq=a.workload.endswith("_pq"),
                      attrs=with_attrs)
    init_attrs = attrs_for(0, N0) if with_attrs else None

    if a.mode == "crash":
        eng = SVFusionEngine(data[:N0], cfg, init_attrs=init_attrs)
        run_ops(eng, data, ops, crash_op=a.crash_op,
                crash_point=a.crash_point)
        return 3                        # armed crash never fired

    if a.mode == "reopen":
        eng = SVFusionEngine(None, cfg)          # recover from --dir
        last_seq = int(eng.stats()["recovered_to_seq"])
        dump_digest(eng, a.out, last_seq)
        eng.close()
        return 0

    eng = SVFusionEngine(data[:N0], cfg, init_attrs=init_attrs)  # clean
    done = run_ops(eng, data, ops, max_records=a.records)
    if done != a.records:
        print(f"clean run executed {done} record ops, wanted {a.records}",
              file=sys.stderr)
        return 4
    dump_digest(eng, a.out, a.records)
    eng.close()
    return 0


if __name__ == "__main__":
    sys.exit(main())
