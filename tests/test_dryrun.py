"""Dry-run smoke: one LM cell + the SVFusion cell lower+compile on the
production 256-chip mesh in a subprocess (the test process keeps its single
real device)."""
import pathlib
import subprocess
import sys

SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")


def _run(arch, shape):
    prog = f"""
import os, sys, tempfile, pathlib
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
sys.path.insert(0, {SRC!r})
import repro.launch.dryrun as dr
dr.RESULTS = pathlib.Path(tempfile.mkdtemp())   # don't touch results/
rec = dr.run_cell({arch!r}, {shape!r}, multi_pod=False, force=True)
assert rec["ok"], rec.get("error")
assert rec["flops_corrected"] > 0
print("CELL_OK", rec["memory"]["temp_bytes"])
"""
    res = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, timeout=560)
    assert res.returncode == 0, res.stderr[-2000:]
    assert "CELL_OK" in res.stdout


def test_dryrun_lm_cell():
    _run("qwen3_0p6b", "decode_32k")


def test_dryrun_svfusion_cell():
    _run("svfusion_msturing", "search_1k")
