"""Filtered & hybrid search property suite: filter-spec compilation /
canonicalization, host-vs-device predicate bit-parity, filtered search
(graph lane AND brute-force fallback lane) bit-compared against exact
post-filtering of an unfiltered full scan across selectivities
{100%, 50%, ~1%, 0 matches}, interleaved insert/delete epoch flushes,
selectivity-router engagement, coalescer filter-compatibility demux, and
per-tenant token-bucket rate limits at the SLO admission gate."""
import tempfile
import threading

import numpy as np
import pytest

from repro.core import cache as C
from repro.core import update
from repro.core.build import build_tiered_backend
from repro.core.engine import EngineConfig, SVFusionEngine
from repro.core.filters import (AttributeSchema, FilterSpec, compile_filter,
                                device_pass_mask, estimate_selectivity,
                                host_pass)
from repro.core.search import search_tiered
from repro.core.tiers import AttributeStore
from repro.core.types import SearchParams

SCHEMA = AttributeSchema(tag_fields=("cat",), num_fields=("score",))


def _mk_attrs(n, rng=None):
    """Deterministic attribute columns: cat = i % 4, score = i / n."""
    return {"cat": np.arange(n) % 4, "score": np.arange(n) / max(n, 1)}


def _attach(be, n):
    a = _mk_attrs(n)
    tags, nums = SCHEMA.coerce(a, n)
    be.attach_attrs(AttributeStore(SCHEMA, be.capacity, tags=tags,
                                   nums=nums))


# selectivity cases over cat = i % 4, score = i / n (n ~ 200):
#   100%  — all-pass numeric range
#   50%   — cat in {0, 1}
#   ~1%   — score in [0, 0.011)
#   0     — impossible range
CASES = [
    ("all", FilterSpec(ranges={"score": (None, None)})),
    ("half", FilterSpec(tags={"cat": {0, 1}})),
    ("one_pct", FilterSpec(ranges={"score": (0.0, 0.011)})),
    ("none", FilterSpec(ranges={"score": (2.0, 3.0)})),
]


# ---------------------------------------------------------------------------
# FilterSpec / schema / predicate unit behavior
# ---------------------------------------------------------------------------

def test_filterspec_canonical_key_and_eq():
    a = FilterSpec(tags={"cat": {2, 0}}, ranges={"score": (0.1, None)})
    b = FilterSpec(tags={"cat": {0, 2}}, ranges={"score": (0.1, None)})
    assert a == b and hash(a) == hash(b) and a.key() == b.key()
    c = FilterSpec(tags={"cat": {0}})
    assert a != c and a.key() != c.key()
    with pytest.raises(ValueError):
        FilterSpec(tags={"cat": set()})          # empty tag set matches nothing


def test_schema_validation_and_meta_roundtrip():
    s = AttributeSchema(tag_fields=("a", "b"), num_fields=("x",),
                        tag_domain=16)
    assert AttributeSchema.from_meta(s.to_meta()) == s
    with pytest.raises(ValueError):
        AttributeSchema(tag_fields=("a",), tag_domain=64)   # > uint32 mask
    with pytest.raises(ValueError):
        compile_filter(FilterSpec(tags={"zzz": {0}}), s)    # unknown field
    with pytest.raises(ValueError):
        compile_filter(FilterSpec(tags={"a": {16}}), s)     # out of domain


def test_host_device_predicate_bit_parity():
    rng = np.random.default_rng(0)
    n = 257
    tags = (np.arange(n) % 4)[:, None].astype(np.int32)
    nums = rng.uniform(size=(n, 1)).astype(np.float32)
    be_attrs = AttributeStore(SCHEMA, 512, tags=tags, nums=nums)
    for _, spec in CASES:
        cf = compile_filter(spec, SCHEMA)
        hm = host_pass(cf, be_attrs.tags, be_attrs.nums)
        dm = np.asarray(device_pass_mask(be_attrs, cf))
        np.testing.assert_array_equal(hm, dm)


def test_estimate_selectivity_small_n_exact_and_deterministic():
    n = 200
    tags, nums = SCHEMA.coerce(_mk_attrs(n), n)
    attrs = AttributeStore(SCHEMA, 512, tags=tags, nums=nums)
    alive = np.zeros(512, bool)
    alive[:n] = True
    cf = compile_filter(FilterSpec(tags={"cat": {0, 1}}), SCHEMA)
    s1 = estimate_selectivity(cf, attrs, alive, n)
    s2 = estimate_selectivity(cf, attrs, alive, n)
    assert s1 == s2 == 0.5           # n <= sample: exact fraction


# ---------------------------------------------------------------------------
# bit-parity vs exact post-filtering of an unfiltered full scan
# ---------------------------------------------------------------------------

def _post_filter_topk(ids, dists, hmask, k):
    """Exact reference: post-filter an unfiltered k=pool result row-wise,
    keep the first k passing entries, pad with -1/+inf."""
    B = ids.shape[0]
    out_i = np.full((B, k), -1, np.int64)
    out_d = np.full((B, k), np.inf, np.float32)
    for b in range(B):
        keep = [(i, d) for i, d in zip(ids[b], dists[b])
                if i >= 0 and np.isfinite(d) and hmask[i]][:k]
        for j, (i, d) in enumerate(keep):
            out_i[b, j], out_d[b, j] = i, d
    return out_i, out_d


def _parity_setup(td, n=220, D=12, deg=8):
    rng = np.random.default_rng(3)
    vecs = rng.normal(size=(n, D)).astype(np.float32)
    queries = rng.normal(size=(4, D)).astype(np.float32)
    be = build_tiered_backend(vecs, deg, td, host_window=64,
                              disk_capacity=512)
    _attach(be, n)
    hp = C.HostPlacement(be.capacity, 16, D)
    return be, hp, vecs, queries


def _entries(n, pool, B):
    """Entry pool covering every id (pool >= n): the entry stage alone
    evaluates the whole dataset, so top-k == exact top-k."""
    return np.tile(np.clip(np.arange(pool), 0, n - 1)[None], (B, 1))


@pytest.mark.parametrize("name,spec", CASES)
@pytest.mark.parametrize("lane", ["graph", "fallback"])
def test_filtered_exact_lane_bit_parity(name, spec, lane):
    """Exact arm: filtered results must be BIT-identical (ids and dists)
    to post-filtering an unfiltered full scan, on both the graph lane
    (threshold 0 -> never fall back) and the forced brute-force lane
    (threshold 1.1 -> always fall back)."""
    pool = 256
    sp = SearchParams(k=10, pool=pool, max_iters=8, beam=2)
    spf = SearchParams(k=pool, pool=pool, max_iters=8, beam=2)
    thresh = 0.0 if lane == "graph" else 1.1
    with tempfile.TemporaryDirectory() as td:
        be, hp, vecs, queries = _parity_setup(td)
        try:
            n = int(be.n)
            ent = _entries(n, pool, len(queries))
            ref = search_tiered(be, hp, queries, 0, spf, entry_ids=ent)
            cf = compile_filter(spec, SCHEMA)
            hmask = host_pass(cf, be.attrs.tags, be.attrs.nums)
            want_i, want_d = _post_filter_topk(
                np.asarray(ref.ids), np.asarray(ref.dists), hmask, sp.k)
            got = search_tiered(be, hp, queries, 0, sp, entry_ids=ent,
                                filter=spec,
                                filter_fallback_selectivity=thresh)
            np.testing.assert_array_equal(np.asarray(got.ids), want_i)
            np.testing.assert_array_equal(np.asarray(got.dists), want_d)
            assert got.filter_path == ("fallback" if lane == "fallback"
                                       else "graph")
        finally:
            be.close()


@pytest.mark.parametrize("lane", ["graph", "fallback"])
def test_filtered_pq_lane_bit_parity(lane):
    """PQ arm with a lossless codebook and rerank_depth == pool: filtered
    results bit-identical to post-filtering the unfiltered PQ run."""
    from test_pq import _lossless_codes
    pool = 256
    sp = SearchParams(k=10, pool=pool, max_iters=8, beam=2)
    spf = SearchParams(k=pool, pool=pool, max_iters=8, beam=2)
    thresh = 0.0 if lane == "graph" else 1.1
    with tempfile.TemporaryDirectory() as td:
        be, hp, vecs, queries = _parity_setup(td)
        try:
            n = int(be.n)
            pq = _lossless_codes(vecs, be.capacity)
            be.attach_pq(pq)
            ent = _entries(n, pool, len(queries))
            ref = search_tiered(be, hp, queries, 0, spf, entry_ids=ent,
                                pq=pq, rerank_depth=pool)
            for name, spec in CASES:
                cf = compile_filter(spec, SCHEMA)
                hmask = host_pass(cf, be.attrs.tags, be.attrs.nums)
                want_i, want_d = _post_filter_topk(
                    np.asarray(ref.ids), np.asarray(ref.dists), hmask,
                    sp.k)
                got = search_tiered(be, hp, queries, 0, sp, entry_ids=ent,
                                    pq=pq, rerank_depth=pool, filter=spec,
                                    filter_fallback_selectivity=thresh)
                np.testing.assert_array_equal(np.asarray(got.ids), want_i,
                                              err_msg=name)
                np.testing.assert_array_equal(np.asarray(got.dists),
                                              want_d, err_msg=name)
        finally:
            be.close()


def test_filtered_parity_across_interleaved_updates():
    """Insert (attribute-bearing) and delete between filtered searches:
    parity must hold at every epoch — fresh ids become filterable the
    moment their INSERT applies, deleted ids vanish from every lane."""
    pool = 256
    sp = SearchParams(k=10, pool=pool, max_iters=8, beam=2)
    spf = SearchParams(k=pool, pool=pool, max_iters=8, beam=2)
    spec = FilterSpec(tags={"cat": {0, 1}})
    rng = np.random.default_rng(5)
    with tempfile.TemporaryDirectory() as td:
        be, hp, vecs, queries = _parity_setup(td, n=180)
        try:
            def check():
                n = int(be.n)
                ent = _entries(n, pool, len(queries))
                ref = search_tiered(be, hp, queries, 0, spf,
                                    entry_ids=ent)
                cf = compile_filter(spec, SCHEMA)
                hmask = host_pass(cf, be.attrs.tags, be.attrs.nums)
                wi, wd = _post_filter_topk(np.asarray(ref.ids),
                                           np.asarray(ref.dists), hmask,
                                           sp.k)
                got = search_tiered(be, hp, queries, 0, sp,
                                    entry_ids=ent, filter=spec,
                                    filter_fallback_selectivity=0.0)
                np.testing.assert_array_equal(np.asarray(got.ids), wi)
                np.testing.assert_array_equal(np.asarray(got.dists), wd)
                return got

            check()
            for round_ in range(2):
                n0 = int(be.n)
                newv = rng.normal(size=(20, 12)).astype(np.float32)
                new_attrs = {"cat": np.arange(n0, n0 + 20) % 4,
                             "score": np.full(20, 0.5)}
                ids, _ = update.insert_tiered(be, hp, newv, sp, 7,
                                              attributes=new_attrs)
                check()
                # delete a slice that includes filter-passing ids
                update.delete_tiered(be, np.asarray(ids[:8]))
                got = check()
                assert not np.isin(np.asarray(ids[:8]),
                                   np.asarray(got.ids)).any()
        finally:
            be.close()


def test_filter_requires_attribute_store():
    with tempfile.TemporaryDirectory() as td:
        rng = np.random.default_rng(0)
        vecs = rng.normal(size=(100, 8)).astype(np.float32)
        be = build_tiered_backend(vecs, 8, td, host_window=32,
                                  disk_capacity=256)
        hp = C.HostPlacement(be.capacity, 16, 8)
        try:
            with pytest.raises(ValueError, match="attribute store"):
                search_tiered(be, hp, vecs[:2], 0,
                              SearchParams(k=5, pool=32),
                              filter=FilterSpec(tags={"cat": {0}}))
            with pytest.raises(ValueError, match="attribute store"):
                update.insert_tiered(be, hp, vecs[:4],
                                     SearchParams(k=5, pool=32), 0,
                                     attributes={"cat": np.zeros(4)})
        finally:
            be.close()


# ---------------------------------------------------------------------------
# selectivity router + engine threading
# ---------------------------------------------------------------------------

def test_selectivity_router_and_stats(tmp_path):
    """Below-threshold filters auto-engage the brute-force fallback and
    the routing decision is visible in engine.stats()."""
    rng = np.random.default_rng(11)
    n, d = 400, 8
    vecs = rng.normal(size=(n, d)).astype(np.float32)
    eng = SVFusionEngine(vecs, EngineConfig(
        degree=8, cache_slots=64, capacity=1024,
        disk_path=str(tmp_path / "t"), disk_capacity=1024,
        host_window=128, search=SearchParams(k=5, pool=64),
        attributes=SCHEMA, filter_fallback_selectivity=0.1,
        coalesce=False), init_attrs=_mk_attrs(n))
    try:
        q = vecs[:2]
        eng.search(q, filter=FilterSpec(tags={"cat": {0, 1}}))   # 50%
        st = eng.stats()
        assert st["filtered_searches"] == 1
        assert st["filter_fallbacks"] == 0
        assert st["filter_last_path"] == "graph"
        ids, dists = eng.search(
            q, filter=FilterSpec(ranges={"score": (0.0, 0.011)}))  # ~1%
        st = eng.stats()
        assert st["filter_fallbacks"] == 1
        assert st["filter_last_path"] == "fallback"
        assert st["filter_last_selectivity"] < 0.1
        assert (ids[ids >= 0] <= 4).all()        # score < 0.011 -> id <= 4
        eng.search(q)                             # unfiltered: counters idle
        assert eng.stats()["filtered_searches"] == 2
    finally:
        eng.close()


def test_engine_device_mode_rejects_filter():
    rng = np.random.default_rng(0)
    vecs = rng.normal(size=(200, 8)).astype(np.float32)
    eng = SVFusionEngine(vecs, EngineConfig(degree=8, capacity=512,
                                            coalesce=False))
    try:
        with pytest.raises(ValueError, match="three-tier"):
            eng.search(vecs[:1], filter=FilterSpec(tags={"cat": {0}}))
        with pytest.raises(ValueError, match="three-tier"):
            eng.insert(vecs[:1], attributes={"cat": [0]})
    finally:
        eng.close()


# ---------------------------------------------------------------------------
# coalescer filter-compatibility demux
# ---------------------------------------------------------------------------

def test_coalescer_filter_demux(tmp_path):
    """Concurrent submissions with two distinct filter specs plus
    unfiltered traffic: only filter-spec-equal requests share a dispatch,
    every caller gets its own filter's results."""
    rng = np.random.default_rng(13)
    n, d = 400, 8
    vecs = rng.normal(size=(n, d)).astype(np.float32)
    eng = SVFusionEngine(vecs, EngineConfig(
        degree=8, cache_slots=64, capacity=1024,
        disk_path=str(tmp_path / "t"), disk_capacity=1024,
        host_window=128, search=SearchParams(k=5, pool=64),
        attributes=SCHEMA, filter_fallback_selectivity=0.0,
        coalesce=True, coalesce_window=5e-3), init_attrs=_mk_attrs(n))
    try:
        spec_a = FilterSpec(tags={"cat": {0}})
        spec_b = FilterSpec(tags={"cat": {1}})
        # equal specs constructed independently must coalesce (key-equal)
        spec_a2 = FilterSpec(tags={"cat": {0}})
        q = rng.normal(size=(1, d)).astype(np.float32)
        futs = []
        for spec in [spec_a, spec_b, None, spec_a2, None, spec_b]:
            futs.append(eng.submit_search(q, filter=spec))
        outs = [f.result() for f in futs]
        for (ids, _), spec in zip(outs, [spec_a, spec_b, None, spec_a2,
                                         None, spec_b]):
            live = ids[ids >= 0]
            if spec is spec_a or spec is spec_a2:
                assert (live % 4 == 0).all()
            elif spec is spec_b:
                assert (live % 4 == 1).all()
        # unfiltered and the two specs can never share a dispatch
        st = eng.stats()
        assert st["coalesce_dispatches"] >= 3
    finally:
        eng.close()


def test_coalescer_demux_under_concurrency(tmp_path):
    """Hammer the scheduler from threads with mixed specs: every result
    must satisfy its own filter (a cross-spec merge would leak ids)."""
    rng = np.random.default_rng(17)
    n, d = 300, 8
    vecs = rng.normal(size=(n, d)).astype(np.float32)
    eng = SVFusionEngine(vecs, EngineConfig(
        degree=8, cache_slots=64, capacity=1024,
        disk_path=str(tmp_path / "t"), disk_capacity=1024,
        host_window=128, search=SearchParams(k=5, pool=64),
        attributes=SCHEMA, filter_fallback_selectivity=0.0,
        coalesce=True, coalesce_window=2e-3), init_attrs=_mk_attrs(n))
    try:
        specs = [None, FilterSpec(tags={"cat": {0}}),
                 FilterSpec(tags={"cat": {1, 2}})]
        errs, results = [], []
        lock = threading.Lock()

        def worker(i):
            spec = specs[i % 3]
            q = rng.normal(size=(1, d)).astype(np.float32)
            try:
                ids, _ = eng.submit_search(q, filter=spec).result(
                    timeout=30)
                with lock:
                    results.append((i % 3, ids))
            except Exception as e:           # pragma: no cover
                with lock:
                    errs.append(e)

        ts = [threading.Thread(target=worker, args=(i,))
              for i in range(18)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        assert not errs
        assert len(results) == 18
        for kind, ids in results:
            live = ids[ids >= 0]
            if kind == 1:
                assert (live % 4 == 0).all()
            elif kind == 2:
                assert np.isin(live % 4, [1, 2]).all()
    finally:
        eng.close()


# ---------------------------------------------------------------------------
# per-tenant token-bucket rate limits (SLO admission)
# ---------------------------------------------------------------------------

def test_token_bucket_serving_tier():
    import time

    from repro.core.slo import (RateLimitError, ServingTier, SLOPolicy)

    class _Ev:
        def set(self):
            pass

    class _Fut:
        def __init__(self, tenant="a"):
            self.error = None
            self.queries = np.zeros((1, 4), np.float32)
            self.tenant = tenant
            self.deadline = None
            self.submitted = time.perf_counter()
            self._event = _Ev()

    tier = ServingTier(SLOPolicy(tenant_rate_limits={"a": (20.0, 2.0)}))
    rejected = []
    for _ in range(5):                      # burst of 5: burst=2 admitted
        f = _Fut()
        if not tier.offer(f):
            assert isinstance(f.error, RateLimitError)
            rejected.append(f)
    assert len(rejected) == 3
    time.sleep(0.2)                         # refill 4 tokens, capped at 2
    admitted = sum(1 for _ in range(5) if tier.offer(_Fut()))
    assert admitted == 2
    st = tier.stats()
    assert st["rate_limited"] == 6
    assert st["tenants"]["a"]["rate_limited"] == 6
    # unlisted tenants are never limited
    for _ in range(4):
        assert tier.offer(_Fut(tenant="b"))
    with pytest.raises(ValueError):
        SLOPolicy(tenant_rate_limits={"a": 0.0}).rate_limit("a")


def test_engine_rate_limit_knob(tmp_path):
    from repro.core.slo import RateLimitError
    rng = np.random.default_rng(19)
    vecs = rng.normal(size=(300, 8)).astype(np.float32)
    eng = SVFusionEngine(vecs, EngineConfig(
        degree=8, cache_slots=64, capacity=1024,
        disk_path=str(tmp_path / "t"), disk_capacity=1024,
        host_window=128, search=SearchParams(k=5, pool=32),
        coalesce=True, slo_tenant_rate_limits={"t0": (1.0, 1.0)}))
    try:
        q = vecs[:1]
        eng.search(q, tenant="t0")           # first request drains the bucket
        with pytest.raises(RateLimitError):
            eng.search(q, tenant="t0")
        eng.search(q, tenant="other")        # unlimited tenant unaffected
        st = eng.stats()["slo"]
        assert st["rate_limited"] == 1
        assert st["tenants"]["t0"]["rate_limited"] == 1
    finally:
        eng.close()
