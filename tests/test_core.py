"""SVFusion core behaviour tests: build/search recall, WAVP semantics,
updates, MVCC merge, engine consistency + hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:   # no network route: replay fixed seeded examples
    from _hypothesis_shim import given, settings, st

from repro.core import cache as C
from repro.core import mvcc
from repro.core import update as U
from repro.core.build import build_graph, build_index, compute_e_in
from repro.core.engine import EngineConfig, SVFusionEngine
from repro.core.search import brute_force_topk, recall_at_k, search_batch
from repro.core.types import SearchParams

N, D, R = 3000, 24, 16
KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def index():
    vecs = jax.random.normal(KEY, (N, D))
    return build_index(vecs, degree=R, cache_slots=384, n_max=8192)


@pytest.fixture(scope="module")
def sp():
    return SearchParams(k=10, pool=64, max_iters=96)


def test_build_graph_invariants(index):
    g = index.graph
    nb = np.asarray(g.nbrs[:N])
    assert (nb < N).all() and int(g.n) == N
    rows = np.arange(N)[:, None]
    assert not (nb == rows).any(), "self-loops"
    # e_in consistent with edges
    np.testing.assert_array_equal(
        np.asarray(compute_e_in(g.nbrs, g.capacity)), np.asarray(g.e_in))


def test_search_recall(index, sp):
    q = jax.random.normal(jax.random.PRNGKey(1), (64, D))
    res = search_batch(index, q, jax.random.PRNGKey(2), sp)
    truth, _ = brute_force_topk(index.graph, q, 10)
    assert float(recall_at_k(res.ids, truth)) > 0.8


def test_partitioned_build_recall():
    vecs = jax.random.normal(KEY, (2000, D))
    stp = build_index(vecs, degree=R, cache_slots=256, n_max=4096,
                      n_partitions=4, cross_samples=256)
    q = jax.random.normal(jax.random.PRNGKey(1), (32, D))
    res = search_batch(stp, q, jax.random.PRNGKey(2),
                       SearchParams(k=10, pool=64, max_iters=96))
    truth, _ = brute_force_topk(stp.graph, q, 10)
    assert float(recall_at_k(res.ids, truth)) > 0.7


def test_wavp_mapping_invariants(index, sp):
    q = jax.random.normal(jax.random.PRNGKey(3), (32, D))
    stt = index
    for i in range(3):
        res = search_batch(stt, q, jax.random.PRNGKey(4 + i), sp)
        stt = C.apply_wavp(stt, res.acc_ids, res.acc_hit, sp, now=i)
    cache = stt.cache
    slot_hid = np.asarray(cache.slot_hid)
    h2d = np.asarray(cache.h2d)
    occ = slot_hid >= 0
    # bijectivity: occupied slots' host ids map back to the slot
    np.testing.assert_array_equal(h2d[slot_hid[occ]], np.where(occ)[0])
    # every mapped host id is stored in that slot
    mapped = np.where(h2d >= 0)[0]
    np.testing.assert_array_equal(slot_hid[h2d[mapped]], mapped)
    # cached vectors hold the right contents
    vec = np.asarray(cache.vectors)[h2d[mapped]]
    np.testing.assert_allclose(vec, np.asarray(stt.graph.vectors)[mapped],
                               rtol=1e-6)
    assert int(stt.stats.hits) + int(stt.stats.misses) \
        == int(stt.stats.accesses)


def test_wavp_never_policy_keeps_cache(index, sp):
    spn = sp._replace(policy="never")
    q = jax.random.normal(jax.random.PRNGKey(5), (16, D))
    res = search_batch(index, q, jax.random.PRNGKey(6), spn)
    st2 = C.apply_wavp(index, res.acc_ids, res.acc_hit, spn)
    np.testing.assert_array_equal(np.asarray(st2.cache.slot_hid),
                                  np.asarray(index.cache.slot_hid))
    assert int(st2.stats.promotions) == 0


def test_theta_threshold_equivalence():
    """Paper §4.3 theory: gain(x) > 0  <=>  F_lambda(x) > theta."""
    t_cpu, t_gpu, t_xfer = 2e-6, 1e-7, 4e-6
    theta = t_xfer / (t_cpu - t_gpu)
    lam = np.linspace(0, 5, 101)
    gain = lam * (t_cpu - t_gpu) - t_xfer
    np.testing.assert_array_equal(gain > 0, lam > theta)


def test_insert_read_after_write(index, sp):
    newv = jax.random.normal(jax.random.PRNGKey(7), (64, D))
    st2, ids, rev = U.insert_batch(index, newv, jax.random.PRNGKey(8), sp)
    res = search_batch(st2, newv, jax.random.PRNGKey(9), sp)
    assert float((res.ids[:, 0] == ids).mean()) > 0.9
    assert rev.v.shape[0] == 64 * R
    # e_in stays consistent
    np.testing.assert_array_equal(
        np.asarray(compute_e_in(st2.graph.nbrs, st2.graph.capacity)),
        np.asarray(st2.graph.e_in))


def test_delete_then_search_excludes(index, sp):
    q = jax.random.normal(jax.random.PRNGKey(10), (16, D))
    truth, _ = brute_force_topk(index.graph, q, 1)
    st2 = U.delete_batch(index, truth[:, 0].astype(jnp.int32))
    res = search_batch(st2, q, jax.random.PRNGKey(11), sp)
    found = np.asarray(res.ids)
    assert not np.isin(np.asarray(truth[:, 0]), found).any()


def test_repair_improves_clustered_deletions(sp):
    vecs = jax.random.normal(KEY, (2000, D))
    stt = build_index(vecs, degree=R, cache_slots=256, n_max=4096)
    center = vecs[0]
    d = jnp.sum((vecs - center) ** 2, 1)
    dead = jnp.argsort(d)[:500].astype(jnp.int32)
    stt = U.delete_batch(stt, dead)
    frac_before = U.affected_fraction(stt.graph)
    n_affected = int((np.asarray(frac_before[:2000]) > 0.5)[
        np.asarray(stt.graph.alive[:2000])].sum())
    st2, nrep = U.repair_affected(stt, max_repair=512)
    assert int(nrep) > 0 and n_affected > 0
    frac_after = U.affected_fraction(st2.graph)
    alive = np.asarray(st2.graph.alive[:2000])
    assert float(np.asarray(frac_after[:2000])[alive].mean()) \
        < float(np.asarray(frac_before[:2000])[alive].mean())


def test_consolidate_removes_dead_edges(index):
    dead = jnp.arange(0, 600, dtype=jnp.int32)
    st2 = U.delete_batch(index, dead)
    st3 = U.consolidate(st2)
    nb = np.asarray(st3.graph.nbrs)
    alive = np.asarray(st3.graph.alive)
    bad = (nb >= 0) & ~alive[np.clip(nb, 0, None)]
    assert bad.sum() == 0


def test_mvcc_merge_preserves_new_vertices(index, sp):
    # snapshot, consolidate it, meanwhile insert into active, then merge
    snap = index
    snap_n = int(snap.graph.n)
    active = U.delete_batch(index, jnp.arange(0, 400, dtype=jnp.int32))
    newv = jax.random.normal(jax.random.PRNGKey(12), (32, D))
    active, ids, rev = U.insert_batch(active, newv, jax.random.PRNGKey(13), sp)
    consolidated = U.consolidate(snap)
    merged = mvcc.merge_consolidated(consolidated, active,
                                     jnp.asarray(snap_n, jnp.int32), rev)
    # new vertices searchable in merged state
    res = search_batch(merged, newv, jax.random.PRNGKey(14), sp)
    assert float((res.ids[:, 0] == ids).mean()) > 0.85
    # deletions from the window remain authoritative
    assert not bool(merged.graph.alive[:400].any())
    # reverse-edge log was applied: new ids appear in old rows
    nb = np.asarray(merged.graph.nbrs[:snap_n])
    assert np.isin(np.asarray(ids), nb).any()


def test_engine_consistency_modes():
    rng = np.random.default_rng(0)
    base = rng.normal(size=(800, D)).astype(np.float32)
    for sync, expect in ((True, 0.9), (False, 0.5)):
        eng = SVFusionEngine(base, EngineConfig(
            degree=R, cache_slots=256, capacity=4096,
            search=SearchParams(k=1, pool=48, max_iters=64),
            sync=sync, stale_refresh=64))
        hits = []
        for i in range(6):
            newv = rng.normal(size=(8, D)).astype(np.float32)
            ids = eng.insert(newv)
            found, _ = eng.search(newv)
            hits.append(float((found[:, 0] == ids).mean()))
        if sync:
            assert np.mean(hits) > expect
        else:
            assert np.mean(hits) < expect


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(2, 24), st.integers(1, 8))
def test_rank_reorder_properties(seed, C_, deg):
    """Rank-based reordering returns a permutation-subset of candidates and
    never invents ids."""
    from repro.core.build import rank_based_reorder
    rng = np.random.default_rng(seed)
    cand = rng.choice(200, size=(2, C_), replace=False).astype(np.int32)
    dists = np.sort(rng.random((2, C_)).astype(np.float32), axis=1)
    nbrs = rng.integers(-1, 200, size=(256, 8)).astype(np.int32)
    out = np.asarray(rank_based_reorder(jnp.asarray(cand),
                                        jnp.asarray(dists),
                                        jnp.asarray(nbrs), deg))
    assert out.shape == (2, deg)
    for b in range(2):
        valid = out[b][out[b] >= 0]
        assert set(valid).issubset(set(cand[b].tolist()))
        assert len(set(valid.tolist())) == len(valid)


def test_vectorized_clock_matches_sequential_semantics():
    """The batched clock (cache.py) must agree with the paper's sequential
    clock on the core invariants: (1) referenced slots survive the sweep,
    (2) among unreferenced slots, lowest-F_lambda occupants leave first."""
    from repro.core.clock_reference import SequentialClock
    rng = np.random.default_rng(0)
    n_slots, n_ids = 8, 64
    f_lam = rng.random(n_ids)

    seq = SequentialClock(n_slots)
    residents = rng.choice(n_ids, n_slots, replace=False)
    for s, rid in enumerate(residents):
        seq.occupant[s] = rid
    protected = [0, 3]
    for s in protected:
        seq.access(s)
    incoming = int(np.argmax(f_lam))          # high-value newcomer
    slot = seq.admit(incoming, f_lam)
    # sequential clock never evicts a referenced slot on the first sweep
    assert slot not in protected
    # and the victim had the minimal F_lambda among unreferenced slots
    unref = [s for s in range(n_slots) if s not in protected and s != slot]
    evicted_f = f_lam[residents[slot]]
    assert evicted_f <= min(f_lam[residents[s]] for s in unref) + 1e-12

    # vectorized clock: same invariants through apply_wavp's eviction rule
    # (empty-first, then ref==0 ascending F_lambda, ref==1 protected)
    empty = np.zeros(n_slots, bool)
    ref = np.zeros(n_slots, np.int8)
    ref[protected] = 1
    occ_score = f_lam[residents]
    evict_key = np.where(ref > 0, np.inf, occ_score)
    victim = int(np.argmin(evict_key))
    assert victim not in protected
    assert occ_score[victim] == evict_key.min()
