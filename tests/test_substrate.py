"""Substrate tests: checkpointing (atomicity/corruption/resume), gradient
compression (error-feedback properties), data pipelines, serving engine,
RAG, tiered disk store."""
import os
import pathlib
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:   # no network route: replay fixed seeded examples
    from _hypothesis_shim import given, settings, st

from repro.configs.base import load_smoke_config
from repro.models import model as Mdl
from repro.train import optimizer as Opt
from repro.train.checkpoint import CheckpointManager
from repro.train.compression import dequantize_int8, ef_compress, quantize_int8
from repro.train.data import WORKLOADS, TokenPipeline
from repro.train import train_loop

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def _tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.int32)},
            "d": jnp.zeros((), jnp.float32)}


def test_checkpoint_roundtrip_and_keep_k(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3):
        t = jax.tree.map(lambda x: x + s, _tree())
        mgr.save(s, t)
    assert mgr.all_steps() == [2, 3]
    s, tree = mgr.restore(_tree())
    assert s == 3
    np.testing.assert_allclose(np.asarray(tree["a"]),
                               np.asarray(_tree()["a"]) + 3)


def test_checkpoint_corruption_falls_back(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3)
    mgr.save(1, _tree())
    mgr.save(2, jax.tree.map(lambda x: x * 2, _tree()))
    # corrupt newest
    victim = next((tmp_path / "step_00000002").glob("leaf_*.npy"))
    victim.write_bytes(b"garbage garbage garbage")
    s, tree = mgr.restore(_tree())
    assert s == 1


def test_train_resume_continues(tmp_path):
    cfg = load_smoke_config("smollm_135m")
    r1 = train_loop.run(cfg, steps=6, batch=2, seq=32,
                        ckpt_dir=tmp_path, ckpt_every=3)
    # second run restores from step 6 and does nothing more
    r2 = train_loop.run(cfg, steps=6, batch=2, seq=32,
                        ckpt_dir=tmp_path, ckpt_every=3)
    assert r2.restored_from == 6 and len(r2.losses) == 0
    # extending steps resumes mid-way
    r3 = train_loop.run(cfg, steps=8, batch=2, seq=32,
                        ckpt_dir=tmp_path, ckpt_every=3)
    assert r3.restored_from == 6 and len(r3.losses) == 2


# ---------------------------------------------------------------------------
# compression
# ---------------------------------------------------------------------------

def test_int8_quantization_bounds():
    x = jax.random.normal(KEY, (16, 64)) * 3.0
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s) - x))
    amax = np.abs(np.asarray(x)).max(-1, keepdims=True)
    assert (err <= amax / 127.0 * 0.501 + 1e-6).all()


def test_error_feedback_accumulates_small_signal():
    """EF must eventually transmit a signal far below one quantization step
    (plain quantization would drop it forever)."""
    x = jnp.full((1, 8), 1e-4)       # tiny constant gradient
    big = jnp.zeros((1, 8)).at[0, 0].set(1.0)  # sets quant step ~1/127
    err = jnp.zeros_like(x)
    total = np.zeros((1, 8), np.float32)
    for _ in range(300):
        deq, err = ef_compress(x + big - big, err)
        total += np.asarray(deq)
    # mean transmitted ~= true signal
    np.testing.assert_allclose(total / 300.0, np.asarray(x), rtol=0.2,
                               atol=2e-5)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_ef_residual_bounded(seed):
    k = jax.random.PRNGKey(seed)
    x = jax.random.normal(k, (4, 32))
    err = jnp.zeros_like(x)
    for _ in range(5):
        _, err = ef_compress(x, err)
        amax = jnp.max(jnp.abs(x + err), axis=-1, keepdims=True)
        assert (np.asarray(jnp.abs(err)) <= np.asarray(amax) / 127.0
                + 1e-5).all()


# ---------------------------------------------------------------------------
# data pipelines
# ---------------------------------------------------------------------------

def test_token_pipeline_shapes_and_determinism():
    p1 = TokenPipeline(512, 2, 16, seed=3)
    b1 = next(p1)
    p1.close()
    p2 = TokenPipeline(512, 2, 16, seed=3)
    b2 = next(p2)
    p2.close()
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (2, 16)


@pytest.mark.parametrize("wname", list(WORKLOADS))
def test_workloads_wellformed(wname):
    kw = {}
    if wname == "msturing_ih":
        wl = WORKLOADS[wname](n_start=256, n_final=1024, dim=8, n_ops=30)
    elif wname == "sliding_window":
        wl = WORKLOADS[wname](n=1000, dim=8, t_max=20)
    elif wname == "expiration_time":
        wl = WORKLOADS[wname](n=1000, dim=8, t_max=20)
    else:
        wl = WORKLOADS[wname](n=1000, dim=8, rounds=2)
    kinds = set()
    n_ins = n_del = 0
    for op in wl:
        kinds.add(op.kind)
        if op.kind == "insert":
            n_ins += len(op.vectors)
        if op.kind == "delete":
            n_del += len(op.ids)
    assert "insert" in kinds and "search" in kinds
    if wname != "msturing_ih":
        assert "delete" in kinds and 0 < n_del <= n_ins


# ---------------------------------------------------------------------------
# serving + RAG
# ---------------------------------------------------------------------------

def test_serve_engine_continuous_batching():
    from repro.serve.engine import Request, ServeEngine
    cfg = load_smoke_config("smollm_135m")
    params = Mdl.init_params(cfg, KEY)
    eng = ServeEngine(cfg, params, slots=3, max_len=48)
    rng = np.random.default_rng(0)
    for i in range(7):
        eng.submit(Request(rid=i, prompt=rng.integers(
            0, cfg.vocab, size=4).astype(np.int32), max_new=3))
    eng.run_until_drained()
    assert len(eng.completed) == 7
    assert all(len(r.tokens) == 3 for r in eng.completed)


def test_rag_freshness():
    """Retrieval must reflect documents ingested moments earlier."""
    from repro.core.engine import EngineConfig
    from repro.core.types import SearchParams
    from repro.serve.rag import Doc, RAGPipeline
    cfg = load_smoke_config("qwen3_0p6b")
    params = Mdl.init_params(cfg, KEY)
    rag = RAGPipeline(cfg, params, EngineConfig(
        degree=8, cache_slots=128, capacity=2048,
        search=SearchParams(k=4, pool=32, max_iters=48)))
    rng = np.random.default_rng(0)
    docs = [Doc(i, rng.integers(0, cfg.vocab, size=12).astype(np.int32))
            for i in range(40)]
    ids = rag.ingest(docs)
    # query with one of the ingested docs -> should retrieve itself
    got = rag.retrieve(docs[7].tokens, k=4)
    assert any(np.array_equal(d.tokens, docs[7].tokens) for d in got)
    aug = rag.augment(docs[7].tokens, k=2, budget=16)
    assert len(aug) > len(docs[7].tokens)
    # eviction removes from retrieval
    rag.evict(ids)
    assert rag.retrieve(docs[7].tokens, k=4) == []


def test_tiered_store_demotion(tmp_path):
    from repro.core.tiers import DiskTier, TieredStore
    n, dim = 256, 8
    disk = DiskTier(str(tmp_path), n, dim, 4)
    data = np.random.default_rng(0).normal(size=(n, dim)).astype(np.float32)
    disk.write(np.arange(n), data, np.zeros((n, 4), np.int32))
    store = TieredStore(disk, host_slots=32)
    f_lam = np.linspace(1, 0, n)
    v, _ = store.fetch(np.arange(64), f_lam)
    np.testing.assert_allclose(v, data[:64], rtol=1e-6)
    assert store.miss_rate == 1.0
    v2, _ = store.fetch(np.arange(24), f_lam)   # resident now (top f_lam)
    np.testing.assert_allclose(v2, data[:24], rtol=1e-6)
    assert store.hits > 0
