"""Per-kernel validation: interpret-mode Pallas vs pure-jnp oracle across a
shape/dtype sweep, plus hypothesis property tests on the merge kernel."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:   # no network route: replay fixed seeded examples
    from _hypothesis_shim import given, settings, st

from repro.kernels.l2_gather.kernel import l2_gather
from repro.kernels.l2_gather.ref import l2_gather_ref
from repro.kernels.topk_merge.kernel import topk_merge
from repro.kernels.topk_merge.ref import topk_merge_ref

KEY = jax.random.PRNGKey(7)


@pytest.mark.parametrize("N,D,B,K", [
    (256, 32, 2, 8), (512, 64, 4, 16), (1024, 128, 3, 32), (128, 256, 1, 4),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_l2_gather_matches_ref(N, D, B, K, dtype):
    table = jax.random.normal(KEY, (N, D), dtype)
    ids = jax.random.randint(KEY, (B, K), 0, N)
    qs = jax.random.normal(jax.random.PRNGKey(1), (B, D), dtype)
    out = l2_gather(table, ids, qs, interpret=True)
    ref = l2_gather_ref(table, ids, qs)
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=tol, atol=tol * D)


@pytest.mark.parametrize("L,R", [(8, 4), (16, 8), (64, 32), (32, 32)])
def test_topk_merge_matches_ref(L, R):
    B = 3
    pd = jax.random.uniform(KEY, (B, L))
    pi = jax.random.randint(KEY, (B, L), 0, 10_000)
    pv = jax.random.bernoulli(KEY, 0.5, (B, L))
    nd = jax.random.uniform(jax.random.PRNGKey(3), (B, R))
    ni = jax.random.randint(jax.random.PRNGKey(3), (B, R), 10_000, 20_000)
    kd, ki, kv = topk_merge(pd, pi, pv, nd, ni, interpret=True)
    rd, ri, rv = topk_merge_ref(pd, pi, pv, nd, ni)
    np.testing.assert_array_equal(np.asarray(kd), np.asarray(rd))
    np.testing.assert_array_equal(np.asarray(ki), np.asarray(ri))
    np.testing.assert_array_equal(np.asarray(kv), np.asarray(rv))


def test_l2_gather_duplicate_and_boundary_ids():
    table = jax.random.normal(KEY, (64, 16))
    ids = jnp.array([[0, 0, 63, 63, 1, 2, 3, 1]])
    qs = jax.random.normal(KEY, (1, 16))
    out = l2_gather(table, ids, qs, interpret=True)
    ref = l2_gather_ref(table, ids, qs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4,
                               atol=1e-3)


def test_l2_gather_invalid_lanes_masked():
    """The frontier executor feeds -1 lanes (padded beam slots, pruned
    edges): the kernel must clamp the DMA index and return +inf, never
    index the table at -1."""
    table = jax.random.normal(KEY, (64, 16))
    ids = jnp.array([[-1, 5, -1, 0, 63, -1, 7, 2]])
    qs = jax.random.normal(KEY, (1, 16))
    out = np.asarray(l2_gather(table, ids, qs, interpret=True))
    ref = np.asarray(l2_gather_ref(table, ids, qs))
    mask = np.asarray(ids) < 0
    assert np.isinf(out[mask]).all() and np.isinf(ref[mask]).all()
    np.testing.assert_allclose(out[~mask], ref[~mask], rtol=1e-4, atol=1e-3)


def test_l2_gather_round_batched_id_matrix():
    """Executor round shape: the (Q, beam·degree) id matrix of a whole
    expansion round, with duplicates across beam slots and -1 padding."""
    beam, deg = 4, 32
    table = jax.random.normal(KEY, (512, 64))
    rng = np.random.default_rng(0)
    ids = rng.integers(0, 512, (3, beam * deg))
    ids[:, rng.integers(0, beam * deg, 17)] = -1   # pruned/padded lanes
    ids[0, :deg] = ids[0, deg:2 * deg]             # cross-beam duplicates
    ids = jnp.asarray(ids, jnp.int32)
    qs = jax.random.normal(jax.random.PRNGKey(2), (3, 64))
    out = l2_gather(table, ids, qs, interpret=True)
    ref = l2_gather_ref(table, ids, qs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4,
                               atol=1e-2)


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 40), st.integers(1, 24), st.integers(0, 2 ** 31 - 1))
def test_topk_merge_properties(L, R, seed):
    """Invariants: output sorted ascending; the best-L multiset of the
    concatenated input distances is preserved."""
    k = jax.random.PRNGKey(seed)
    pd = jnp.sort(jax.random.uniform(k, (2, L)), axis=1)
    pi = jax.random.randint(k, (2, L), 0, 1000)
    pv = jax.random.bernoulli(k, 0.3, (2, L))
    nd = jax.random.uniform(jax.random.fold_in(k, 1), (2, R))
    ni = jax.random.randint(jax.random.fold_in(k, 1), (2, R), 1000, 2000)
    kd, ki, kv = topk_merge(pd, pi, pv, nd, ni, interpret=True)
    kd = np.asarray(kd)
    assert (np.diff(kd, axis=1) >= 0).all()
    alld = np.sort(np.concatenate([np.asarray(pd), np.asarray(nd)], 1), 1)
    np.testing.assert_allclose(kd, alld[:, :L])
