"""Distributed tests run in a subprocess with 8 fake devices (so the main
test process keeps its single real device; the dry-run owns 512)."""
import json
import pathlib
import subprocess
import sys
import textwrap

import pytest

SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")


def run_sub(body: str) -> dict:
    prog = textwrap.dedent("""
        import os, json, sys
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        sys.path.insert(0, {src!r})
        import jax, jax.numpy as jnp, numpy as np
        from repro import compat
        out = {{}}
    """).format(src=SRC) + textwrap.dedent(body) + \
        "\nprint('RESULT::' + json.dumps(out))\n"
    res = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, timeout=560)
    assert res.returncode == 0, res.stderr[-3000:]
    line = [l for l in res.stdout.splitlines()
            if l.startswith("RESULT::")][-1]
    return json.loads(line[len("RESULT::"):])


def test_distributed_search_matches_single_device():
    out = run_sub("""
        from repro.launch.mesh import make_test_mesh
        from repro.core.distributed import make_distributed_search
        from repro.core.types import SearchParams
        from repro.core.build import build_graph
        from repro.core.search import brute_force_topk, recall_at_k

        mesh = make_test_mesh((2, 4), ("data", "model"))
        sp = SearchParams(k=10, pool=48, max_iters=64)
        step = make_distributed_search(mesh, sp, data_axes=("data",),
                                       query_axis="model")
        N, D, R = 2000, 16, 8
        rng = np.random.default_rng(0)
        vecs = rng.normal(size=(N, D)).astype(np.float32)
        parts = [build_graph(vecs[i*1000:(i+1)*1000], R) for i in range(2)]
        idx = {
          "vectors": np.concatenate([np.asarray(g.vectors) for g in parts]),
          "nbrs": np.concatenate([np.asarray(g.nbrs) for g in parts]),
          "alive": np.concatenate([np.asarray(g.alive) for g in parts]),
          "e_in": np.concatenate([np.asarray(g.e_in) for g in parts]),
          "cache_vectors": np.zeros((2*64, D), np.float32),
          "slot_hid": np.full((2*64,), -1, np.int32),
          "h2d": np.full((N,), -1, np.int32),
          "f_recent": np.zeros((N,), np.float32),
        }
        Q = rng.normal(size=(32, D)).astype(np.float32)
        with compat.use_mesh(mesh):
            jidx = {k: jnp.asarray(v) for k, v in idx.items()}
            ids, dists = jax.jit(step)(jidx, jnp.asarray(Q),
                                       jax.random.PRNGKey(0))
            ids.block_until_ready()
        gfull = build_graph(vecs, R)
        ti, _ = brute_force_topk(gfull, jnp.asarray(Q), 10)
        out["recall"] = float(recall_at_k(jnp.asarray(np.asarray(ids)), ti))
        d = np.asarray(dists)
        out["sorted"] = bool((np.diff(d, axis=1) >= -1e-5).all())
    """)
    assert out["recall"] > 0.75
    assert out["sorted"]


def test_data_parallel_train_matches_single_device():
    out = run_sub("""
        from repro.configs.base import load_smoke_config
        from repro.models import model as Mdl
        from repro.launch.mesh import make_test_mesh
        from jax.sharding import PartitionSpec as P

        cfg = load_smoke_config("smollm_135m")
        params = Mdl.init_params(cfg, jax.random.PRNGKey(0))
        tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0,
                                    cfg.vocab)
        batch = {"tokens": tokens, "labels": tokens}
        loss_single = float(Mdl.loss_fn(cfg, params, batch))

        mesh = make_test_mesh((4, 2), ("data", "model"))
        with compat.use_mesh(mesh):
            p_spec = Mdl.param_specs(cfg)
            b_spec = {"tokens": P("data", None), "labels": P("data", None)}
            f = jax.jit(lambda p, b: Mdl.loss_fn(cfg, p, b),
                        in_shardings=compat.resolve_shardings(
                            (p_spec, b_spec)))
            loss_sharded = float(f(params, batch))
        out["single"] = loss_single
        out["sharded"] = loss_sharded
    """)
    assert abs(out["single"] - out["sharded"]) / abs(out["single"]) < 2e-2


def test_seq_sharded_decode_attention_no_kv_allgather():
    """long-context decode: KV sharded on sequence must lower to a partial
    softmax + all-reduce (flash-decoding combine), NOT a KV all-gather."""
    out = run_sub("""
        from repro.launch.mesh import make_test_mesh
        from repro.models.layers import decode_attention
        from jax.sharding import PartitionSpec as P
        import re

        mesh = make_test_mesh((1, 8), ("data", "model"))
        B, T, H, Dh = 2, 1024, 4, 16
        q = jax.ShapeDtypeStruct((B, 1, H, Dh), jnp.bfloat16)
        kv = jax.ShapeDtypeStruct((B, T, H, Dh), jnp.bfloat16)
        with compat.use_mesh(mesh):
            low = jax.jit(lambda q, k, v: decode_attention(q, k, v, T),
                          in_shardings=compat.resolve_shardings(
                              (P(), P(None, "model", None, None),
                               P(None, "model", None, None)))
                          ).lower(q, kv, kv)
            txt = low.compile().as_text()
        kv_bytes = B*T*H*Dh*2
        ags = []
        for line in txt.splitlines():
            m = re.search(r'= ([a-z0-9]+)\\[([0-9,]+)\\][^ ]* all-gather', line)
            if m:
                n = 1
                for dd in m.group(2).split(','):
                    n *= int(dd)
                ags.append(n)
        out["max_allgather_elems"] = max(ags) if ags else 0
        out["kv_elems"] = B*T*H*Dh
        out["has_allreduce"] = "all-reduce" in txt
    """)
    # no all-gather anywhere near the KV size; combine happens via reduce
    assert out["max_allgather_elems"] < out["kv_elems"] // 4
    assert out["has_allreduce"]


def test_elastic_remesh_preserves_values():
    out = run_sub("""
        from repro.launch.mesh import make_test_mesh
        from repro.train.compression import remesh
        from jax.sharding import PartitionSpec as P

        big = make_test_mesh((4, 2), ("data", "model"))
        small = make_test_mesh((2, 2), ("data", "model"))
        x = jnp.arange(64.0).reshape(8, 8)
        tree = {"w": x, "b": jnp.ones((8,))}
        spec = {"w": P("data", "model"), "b": P("data")}
        with compat.use_mesh(big):
            placed = jax.tree.map(
                lambda a, s: jax.device_put(
                    a, jax.NamedSharding(big, s)), tree, spec)
        moved = remesh(placed, spec, small)
        out["ok"] = bool(jnp.allclose(moved["w"], x)
                         and jnp.allclose(moved["b"], 1.0))
        out["ndev"] = len(moved["w"].sharding.device_set)
    """)
    assert out["ok"] and out["ndev"] == 4


def test_crosspod_ef_int8_grad_sync():
    out = run_sub("""
        from repro.launch.mesh import make_test_mesh
        from repro.train.compression import ef_int8_psum
        from functools import partial
        from jax.sharding import PartitionSpec as P

        mesh = make_test_mesh((2, 4), ("pod", "data"))
        g = jax.random.normal(jax.random.PRNGKey(0), (8, 64))
        # per-pod gradients differ; EF-int8 pmean over "pod"
        gp = jnp.stack([g, g * 3.0])     # pod-major view
        fn = compat.shard_map(partial(ef_int8_psum, axis_name="pod"),
                           mesh=mesh,
                           in_specs=(P("pod", "data"), P("pod", "data")),
                           out_specs=(P("pod", "data"), P("pod", "data")))
        with compat.use_mesh(mesh):
            synced, err = fn(gp.reshape(16, 64), jnp.zeros((16, 64)))
        true_mean = np.asarray((g + 3*g) / 2.0)
        got = np.asarray(synced)[:8]
        rel = np.abs(got - true_mean).max() / np.abs(true_mean).max()
        out["rel_err"] = float(rel)
    """)
    assert out["rel_err"] < 0.02
