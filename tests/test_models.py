"""Per-architecture smoke tests: reduced configs, one forward/train step on
CPU, shape + finiteness assertions, and prefill/decode parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, load_config, load_smoke_config
from repro.models import model as Mdl

KEY = jax.random.PRNGKey(0)


def make_batch(cfg, B=2, S=33):
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens,
             "mask": jnp.ones((B, S), jnp.float32)}
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            KEY, (B, cfg.n_patches, cfg.d_model), jnp.float32)
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(KEY, (B, 16, cfg.d_model),
                                            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_loads(arch):
    cfg = load_config(arch)
    assert cfg.n_layers >= 24 and cfg.vocab > 30000


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = load_smoke_config(arch)
    params = Mdl.init_params(cfg, KEY)
    batch = make_batch(cfg)

    loss, grads = jax.value_and_grad(
        lambda p: Mdl.loss_fn(cfg, p, batch))(params)
    assert np.isfinite(float(loss))
    gnorm = sum(float(jnp.sum(g.astype(jnp.float32) ** 2))
                for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0

    # one SGD step decreases loss locally
    params2 = jax.tree.map(lambda p, g: p - 0.05 * g.astype(p.dtype),
                           params, grads)
    loss2 = Mdl.loss_fn(cfg, params2, batch)
    assert float(loss2) < float(loss)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_parity(arch):
    cfg = load_smoke_config(arch)
    if cfg.family == "moe":
        cfg = cfg.replace(moe_impl="dense")  # capacity dispatch is
        # batch-grouping dependent; dense impl is the exact oracle
    params = Mdl.init_params(cfg, KEY)
    B, S = 2, 32
    batch = make_batch(cfg, B, S + 1)
    tokens = batch["tokens"]

    if cfg.family == "encdec":
        full = Mdl.forward_encdec(cfg, params, batch["frames"], tokens)
        pre_in = {"frames": batch["frames"], "tokens": tokens[:, :S]}
    else:
        full = Mdl.forward_lm(cfg, params, tokens, batch.get("patches"))
        pre_in = {k: (v[:, :S] if k == "tokens" else v)
                  for k, v in batch.items() if k in ("tokens", "patches")}
    lg_pre, cache = Mdl.prefill(cfg, params, pre_in, max_len=64)
    lg_dec, cache2 = Mdl.decode_step(cfg, params, cache, tokens[:, S:S + 1])
    off = cfg.n_patches if cfg.family == "vlm" else 0

    np.testing.assert_allclose(np.asarray(full[:, off + S - 1]),
                               np.asarray(lg_pre[:, 0]), rtol=2e-2, atol=1e-2)
    np.testing.assert_allclose(np.asarray(full[:, off + S]),
                               np.asarray(lg_dec[:, 0]), rtol=2e-2, atol=1e-2)
    assert int(cache2["len"]) == S + 1 + off  # vlm caches patch positions too


def test_moe_capacity_close_to_dense():
    cfg = load_smoke_config("granite_moe_1b").replace(capacity_factor=8.0)
    params = Mdl.init_params(cfg, KEY)
    tokens = jax.random.randint(KEY, (2, 32), 0, cfg.vocab)
    y_cap = Mdl.forward_lm(cfg, params, tokens)
    y_dense = Mdl.forward_lm(cfg.replace(moe_impl="dense"), params, tokens)
    # with generous capacity no tokens drop -> implementations agree
    np.testing.assert_allclose(np.asarray(y_cap), np.asarray(y_dense),
                               rtol=3e-2, atol=3e-2)


def test_multi_step_training_decreases_loss():
    cfg = load_smoke_config("smollm_135m")
    params = Mdl.init_params(cfg, KEY)
    batch = make_batch(cfg, B=4, S=64)
    losses = []

    @jax.jit
    def step(p):
        l, g = jax.value_and_grad(lambda q: Mdl.loss_fn(cfg, q, batch))(p)
        return l, jax.tree.map(lambda a, b: a - 0.03 * b.astype(a.dtype), p, g)

    for _ in range(8):
        loss, params = step(params)
        losses.append(float(loss))
    # robust trend check: strictly improving on average, meaningful delta
    assert np.mean(losses[-3:]) < np.mean(losses[:3]) - 0.03
    assert losses[-1] < losses[0]
